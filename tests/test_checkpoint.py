"""checkpoint/io.py: round-trip fidelity + loud failure on corrupt files."""
import json

import numpy as np
import pytest

from repro.checkpoint.io import load_checkpoint, save_checkpoint


def _tree():
    return {
        "layers": [
            {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
             "b": np.zeros(3, np.float32)},
            {"w": np.ones((2, 3), np.float32),
             "b": np.full(3, -1.0, np.float32)},
        ],
        "head": {"scale": np.float32(0.5),
                 "ids": np.array([3, 1, 2], np.int32)},
    }


def _assert_tree_equal(a, b):
    if isinstance(a, dict):
        assert isinstance(b, dict)
        assert sorted(a) == sorted(b)
        for k in a:
            _assert_tree_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert isinstance(b, (list, tuple)) and len(a) == len(b)
        for x, y in zip(a, b):
            _assert_tree_equal(x, y)
    else:
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_round_trip_values_and_structure(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, _tree(), metadata={"round": 7, "tag": "smoke"})
    tree, meta = load_checkpoint(path)
    _assert_tree_equal(_tree(), tree)
    assert meta == {"round": 7, "tag": "smoke"}


def test_round_trip_without_metadata_and_ext_autocomplete(tmp_path):
    # save under "ckpt" (np.savez appends .npz), load under "ckpt" too
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, {"x": np.arange(4)})
    tree, meta = load_checkpoint(path)
    assert meta is None
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.arange(4))


def test_missing_file_raises_file_not_found(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path / "nope.npz"))


def test_corrupt_file_raises_value_error(tmp_path):
    path = tmp_path / "bad.npz"
    path.write_bytes(b"this is not a zip archive")
    with pytest.raises(ValueError, match="corrupt or unreadable"):
        load_checkpoint(str(path))


def test_truncated_file_raises_value_error(tmp_path):
    path = str(tmp_path / "trunc.npz")
    save_checkpoint(path, _tree())
    raw = open(path, "rb").read()
    open(path, "wb").write(raw[: len(raw) // 2])
    with pytest.raises(ValueError, match=r"trunc\.npz"):
        load_checkpoint(path)


def test_corrupt_metadata_raises_value_error(tmp_path):
    path = str(tmp_path / "meta.npz")
    np.savez(path, __meta__=np.frombuffer(b"{not json", dtype=np.uint8),
             x=np.zeros(2))
    with pytest.raises(ValueError, match="metadata"):
        load_checkpoint(path)


def test_metadata_survives_non_ascii(tmp_path):
    path = str(tmp_path / "uni.npz")
    meta = {"note": "réid — ♥", "k": [1, 2]}
    save_checkpoint(path, {"x": np.zeros(1)}, metadata=meta)
    _, got = load_checkpoint(path)
    assert got == meta
    assert json.dumps(got)          # still JSON-serializable
