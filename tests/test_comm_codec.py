"""Wire-format codec subsystem tests: stage round-trips (exact for
lossless, bounded + deterministic for lossy), host-vs-batched parity,
measured-vs-formula accounting, the FedWeIT sparse-bytes formula fix, and
the end-to-end fidelity guard (codec-on FedSTIL within tolerance of the
uncompressed run at under half the dense-FedAvg payload)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.accounting import CommLog
from repro.comm.batched import BatchedCodec
from repro.comm.codec import (grouped_topk_select_host,
                              make_codec, quantize_host, topk_select_host)
from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.federated import FedAvg, run_simulation


def _tree(rng, scale=1.0):
    return {"a": {"w": rng.standard_normal((13, 7)).astype(np.float32) * scale,
                  "b": rng.standard_normal((7,)).astype(np.float32)},
            "c": rng.standard_normal((41,)).astype(np.float32)}


# ---- lossless stages --------------------------------------------------------

def test_raw_roundtrip_exact():
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    codec = make_codec("raw")
    payload = codec.encode(tree)
    dec = codec.decode(payload)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
        np.testing.assert_array_equal(a, b)
        assert a.dtype == b.dtype and a.shape == b.shape
    assert payload.nbytes == sum(l.nbytes for l in jax.tree.leaves(tree))


def test_delta_raw_stream_reconstructs():
    """delta+raw over a drifting stream: every round reconstructs the
    current payload (residual + reference is exact in fp32 up to the
    subtract/add round-trip)."""
    rng = np.random.default_rng(1)
    codec = make_codec("delta")
    base = rng.standard_normal(257).astype(np.float32)
    for r in range(4):
        tree = {"w": base + 0.1 * r}
        dec = codec.decode(codec.encode(tree, peer=0), peer=0)
        np.testing.assert_allclose(dec["w"], tree["w"], atol=1e-6, rtol=0)


# ---- lossy stages: bounded error + determinism ------------------------------

def test_int8_error_bound_and_determinism():
    rng = np.random.default_rng(2)
    tree = _tree(rng, scale=3.0)
    codec = make_codec("int8", chunk=16)
    p1 = codec.encode(tree)
    p2 = codec.encode(tree)
    for k in p1.buffers:
        np.testing.assert_array_equal(p1.buffers[k], p2.buffers[k])
    dec = codec.decode(p1)
    flat = np.concatenate([l.ravel() for l in jax.tree.leaves(tree)])
    rec = np.concatenate([l.ravel() for l in jax.tree.leaves(dec)])
    err = np.abs(flat - rec)
    # per-chunk scale = chunk absmax/127, round-to-nearest: err <= scale/2
    for o in range(0, flat.size, 16):
        chunk = flat[o:o + 16]
        bound = np.abs(chunk).max() / 127.0 * 0.5 + 1e-7
        assert err[o:o + 16].max() <= bound


def test_bf16_roundtrip_bound():
    rng = np.random.default_rng(3)
    tree = _tree(rng)
    codec = make_codec("bf16")
    payload = codec.encode(tree)
    assert payload.nbytes == sum(l.size * 2 + 0 for l in jax.tree.leaves(tree))
    dec = codec.decode(payload)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dec)):
        np.testing.assert_allclose(a, b, rtol=1e-2, atol=1e-6)


def test_grouped_topk_invariants():
    """Grouped selection keeps exactly kg per group, the kg largest
    magnitudes, ties by lowest index, deterministically."""
    rng = np.random.default_rng(4)
    x = rng.standard_normal(80).astype(np.float32)
    x[8:16] = 1.0                       # a full group of exact ties
    v1, i1 = grouped_topk_select_host(x, 8, 3)
    v2, i2 = grouped_topk_select_host(x, 8, 3)
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(i1, i2)
    assert len(v1) == 80 // 8 * 3
    for b in range(10):
        grp = np.abs(x[b * 8:(b + 1) * 8])
        kept = sorted(i1[(i1 >= b * 8) & (i1 < (b + 1) * 8)] - b * 8)
        order = np.argsort(-grp, kind="stable")[:3]     # ties: lowest index
        assert kept == sorted(order), (b, kept, order)
    # the tie group keeps its first three indices
    assert sorted(i1[(i1 >= 8) & (i1 < 16)]) == [8, 9, 10]


def test_global_topk_tie_semantics():
    """Exact global top-k: entries strictly above the threshold always
    survive; ties at the threshold are kept by lowest index."""
    x = np.array([1.0, 1.0, 1.0, 5.0], np.float32)
    vals, idx = topk_select_host(x, 2)
    assert 3 in idx                     # the 5 must survive the tie pile
    assert list(idx) == [0, 3]
    vals, idx = topk_select_host(x, 3)
    assert list(idx) == [0, 1, 3]


def test_topk_codec_reconstruction_and_keyframe():
    """topk+int8 (delta default ON): the first payload is a dense
    keyframe; later payloads are sparse residuals whose reconstruction
    error shrinks on a static stream."""
    rng = np.random.default_rng(5)
    tree = {"w": rng.standard_normal(4096).astype(np.float32)}
    codec = make_codec("topk+int8")
    p0 = codec.encode(tree, peer=0)
    assert "idx_bits" not in p0.buffers         # keyframe ships dense
    d0 = codec.decode(p0, peer=0)
    p1 = codec.encode(tree, peer=0)
    assert "idx_bits" in p1.buffers             # residuals ship sparse
    d1 = codec.decode(p1, peer=0)
    e0 = np.abs(d0["w"] - tree["w"]).max()
    e1 = np.abs(d1["w"] - tree["w"]).max()
    assert e1 <= e0 + 1e-7
    # grouped indices ship bit-packed: 3 bits per kept slot at group=8
    k = p1.schema["k"]
    assert p1.buffers["idx_bits"].dtype == np.uint8
    assert p1.buffers["idx_bits"].nbytes == 3 * ((k + 7) // 8)
    # stateless variant: sparse from the first payload
    stateless = make_codec("topk+int8", delta=False)
    ps = stateless.encode(tree, peer=0)
    assert "idx_bits" in ps.buffers
    dec = stateless.decode(ps, peer=0)
    kept = dec["w"] != 0
    assert kept.sum() == ps.schema["k"]


# ---- host vs batched parity -------------------------------------------------

@pytest.mark.parametrize("spec", ["int8", "topk+int8", "topk"])
def test_host_vs_batched_parity(spec):
    """The numpy host codec and the jitted batched device program are the
    same codec: identical wire bytes and bit-identical reconstructions
    (including over a delta stream with its keyframe)."""
    rng = np.random.default_rng(6)
    C, P = 4, 999
    host = make_codec(spec)
    batched = BatchedCodec(make_codec(spec), P)
    for r in range(3):
        mat = rng.standard_normal((C, P)).astype(np.float32) * (1 + r)
        buffers = batched.encode(jnp.asarray(mat))
        dec_b = np.asarray(batched.decode(buffers))
        per_client = batched.per_client_bytes(buffers)
        for c in range(C):
            payload = host.encode({"w": mat[c]}, peer=c)
            assert payload.nbytes == per_client
            dec_h = host.decode(payload, peer=c)["w"]
            np.testing.assert_allclose(dec_h, dec_b[c], atol=1e-6, rtol=0)


def test_batched_rejects_global_topk():
    with pytest.raises(ValueError):
        BatchedCodec(make_codec("topk", k=10), 100)


# ---- accounting: measured vs formula ---------------------------------------

def test_commlog_measured_vs_formula():
    log = CommLog()
    log.log_c2s(0, 1000)
    assert not log.measured
    log.log_c2s(1, 1000, measured=300)
    log.log_s2c_many(1, 500, 3, measured=100)
    assert log.measured
    assert log.total_c2s == 1300 and log.total_c2s_formula == 2000
    assert log.total_s2c == 300 and log.total_s2c_formula == 1500
    rows = log.round_breakdown()
    assert rows[1] == {"round": 1, "c2s_wire": 300, "s2c_wire": 300,
                       "c2s_formula": 1000, "s2c_formula": 1500}


def test_fedweit_sparse_bytes_matches_measured():
    """Satellite fix: FedWeIT's formula counts the ACTUAL nonzeros of the
    sparsified A (ties at the top-k threshold keep > k entries), and that
    formula equals the measured bytes of a lossless sparse encoding."""
    cfg = EdgeModelConfig(n_classes=16)
    from repro.federated import FedWeIT
    s = FedWeIT(cfg, n_clients=3)
    rng = np.random.default_rng(7)
    A = {"l1": {"w": rng.standard_normal((32, 16)).astype(np.float32)}}
    # force ties at the threshold: duplicate the k-th magnitude
    flat = A["l1"]["w"].ravel()
    flat[:5] = 0.5
    A_sp = s._sparsify(A)
    nnz = int(sum(np.count_nonzero(np.asarray(l))
                  for l in jax.tree.leaves(A_sp)))
    total = sum(l.size for l in jax.tree.leaves(A_sp))
    formula = s.sparse_bytes(A_sp)
    assert formula == nnz * 8
    # ties can keep more than the closed-form k = total * keep_frac
    assert nnz >= int(total * 0.3)
    # measured: lossless global top-nnz encoding of the sparse tree picks
    # exactly the nonzeros -> values (4B) + indices (4B) per kept entry
    codec = make_codec("topk", k=nnz, delta=False)
    payload = codec.encode(A_sp)
    assert payload.nbytes == formula
    dec = codec.decode(payload)
    np.testing.assert_array_equal(dec["l1"]["w"],
                                  np.asarray(A_sp["l1"]["w"]))


# ---- end-to-end fidelity guard (tier-1) ------------------------------------

@pytest.fixture(scope="module")
def bench():
    return FederatedReIDBenchmark(n_clients=3, n_tasks=3, n_identities=60,
                                  ids_per_task=10, samples_per_id=8, seed=1)


def test_fedstil_codec_fidelity_guard(bench):
    """FedSTIL with the default wire codec stays within tolerance of the
    uncompressed run while moving < half the dense FedAvg payload."""
    cfg = EdgeModelConfig(n_classes=bench.n_classes)
    base = run_simulation(FedSTIL(cfg, n_clients=3, epochs=3), bench,
                          rounds=6, eval_every=3)
    coded = run_simulation(
        FedSTIL(cfg, n_clients=3, epochs=3, codec="topk+int8"), bench,
        rounds=6, eval_every=3)
    avg = run_simulation(FedAvg(cfg, epochs=3), bench, rounds=6, eval_every=3)
    assert coded.comm.measured
    assert coded.final("mAP") >= base.final("mAP") - 0.03
    # measured wire strictly below dense FedAvg, and >= 50% below
    assert coded.comm.total < 0.5 * avg.comm.total
    # formulas keep reporting the dense payload as the cross-check oracle
    assert coded.comm.total < coded.comm.total_formula
    rows = coded.comm_breakdown()
    assert rows and all(r["c2s_wire"] <= r["c2s_formula"] for r in rows)


def test_stacked_engine_codec_matches_host(bench):
    """Both engines run the same wire codec: same measured bytes (up to
    the stacked engine's per-client nz bitmap) and metrics in tolerance.

    Byte parity holds because this bench dispatches to every client from
    round 0 (nz all-true); under partial nz the stacked engine's broadcast
    wire model deliberately counts all C rows (see simulation.py)."""
    cfg = EdgeModelConfig(n_classes=bench.n_classes)
    host = run_simulation(
        FedSTIL(cfg, n_clients=3, epochs=2, codec="topk+int8"), bench,
        rounds=4, eval_every=2)
    stacked = run_simulation(
        FedSTIL(cfg, n_clients=3, epochs=2, codec="topk+int8"), bench,
        rounds=4, eval_every=2, engine="stacked")
    assert abs(stacked.comm.total - host.comm.total) <= 4 * 3  # nz bytes
    assert abs(stacked.final("mAP") - host.final("mAP")) < 0.02


def test_quantize_host_zero_chunk():
    q, s = quantize_host(np.zeros(10, np.float32), 4)
    assert (q == 0).all() and (s == 1.0).all()


def test_make_codec_rejects_unknown():
    with pytest.raises(ValueError):
        make_codec("topk+gzip")
    with pytest.raises(ValueError):
        make_codec("int8+bf16")
    assert make_codec(None) is None


def test_fedweit_codec_keeps_counters_out_of_wire(bench):
    """FedWeIT's A_nnz/neighbors_nnz accounting counters ship verbatim:
    a large integer must never share a quantization chunk with A entries
    (it would inflate the chunk scale ~50x). The sim must run and report
    measured < formula."""
    from repro.federated import FedWeIT
    cfg = EdgeModelConfig(n_classes=bench.n_classes)
    res = run_simulation(FedWeIT(cfg, epochs=2, n_clients=3, codec="int8"),
                         bench, rounds=2, eval_every=2)
    assert res.comm.measured
    assert res.comm.total < res.comm.total_formula
    assert np.isfinite(res.final("mAP"))


def test_simulation_codec_int8_fedavg(bench):
    """A non-FedSTIL strategy picks up codecs through the same hooks:
    int8 wire ~ 1/4 the formula bytes, measured flag set."""
    cfg = EdgeModelConfig(n_classes=bench.n_classes)
    res = run_simulation(FedAvg(cfg, epochs=2, codec="int8"), bench,
                         rounds=2, eval_every=2)
    assert res.comm.measured
    assert res.comm.total < 0.30 * res.comm.total_formula
    assert np.isfinite(res.final("mAP"))