"""Regression tests: the vectorized server round (batched relevance +
kernel-backed Eq. 6 aggregation) matches the retained loop reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import personalized_aggregate
from repro.core.relevance import RelevanceTracker, decayed_relevance, normalize_rows


def _filled_tracker(C, metric, *, history_len=4, ragged=True, seed=0, D=16):
    """Tracker with a ragged history: client j has j pushes (0 = empty)
    when ragged, else a full history for everyone (plus one overflow push
    so the history-cap path is exercised)."""
    rng = np.random.default_rng(seed)
    tr = RelevanceTracker(C, history_len=history_len, forgetting_ratio=0.5,
                          metric=metric)
    for j in range(C):
        n = j if ragged else history_len + 1
        for _ in range(n):
            tr.push(j, rng.standard_normal(D).astype(np.float32))
    return tr


@pytest.mark.parametrize("metric", ["kl", "cosine", "euclidean"])
@pytest.mark.parametrize("C", [1, 2, 5])
@pytest.mark.parametrize("ragged", [True, False])
def test_batched_relevance_matches_loop(metric, C, ragged):
    tr = _filled_tracker(C, metric, ragged=ragged)
    W_loop = tr.relevance(backend="loop")
    W_batched = tr.relevance()
    assert W_batched.shape == (C, C)
    np.testing.assert_allclose(W_batched, W_loop, atol=1e-5)
    assert np.allclose(np.diag(W_batched), 0.0)
    rows = W_batched.sum(1)
    assert ((np.isclose(rows, 1.0, atol=1e-4)) | (rows == 0)).all()


def test_batched_relevance_interpret_kernel_matches_loop():
    tr = _filled_tracker(5, "kl", ragged=True)
    np.testing.assert_allclose(tr.relevance(backend="interpret"),
                               tr.relevance(backend="loop"), atol=1e-5)


def test_relevance_empty_history_is_all_zero():
    tr = RelevanceTracker(3, history_len=4)
    for backend in ("loop", None):
        W = tr.relevance(backend=backend)
        assert W.shape == (3, 3) and (W == 0).all()


def test_decayed_relevance_validity_mask():
    """Padded history slots must contribute nothing."""
    rng = np.random.default_rng(1)
    cur = jnp.asarray(rng.standard_normal((2, 8)).astype(np.float32))
    hist = jnp.asarray(rng.standard_normal((3, 4, 8)).astype(np.float32))
    decay = jnp.asarray(0.5 ** np.arange(4, dtype=np.float32))
    valid = jnp.asarray(np.array([[1, 1, 0, 0], [1, 0, 0, 0], [1, 1, 1, 1]],
                                 np.float32))
    W = decayed_relevance(cur, hist, decay, valid, metric="kl")
    hist_zeroed = hist * valid[:, :, None]
    W2 = decayed_relevance(cur, hist_zeroed, decay, valid, metric="kl")
    np.testing.assert_allclose(np.asarray(W), np.asarray(W2), atol=1e-6)


def test_normalize_rows_zero_row_safe():
    W = np.array([[0.0, 0.0], [3.0, 1.0]], np.float32)
    out = normalize_rows(W)
    assert not np.isnan(out).any()
    np.testing.assert_allclose(out, [[0.0, 0.0], [0.75, 0.25]])


def _random_thetas(C, seed=0):
    rng = np.random.default_rng(seed)
    return [{"alpha": jnp.asarray(rng.standard_normal((3, 4)).astype(np.float32)),
             "A": [jnp.asarray(rng.standard_normal(7).astype(np.float32)),
                   jnp.asarray(rng.standard_normal((2, 2)).astype(np.float32))]}
            for _ in range(C)]


@pytest.mark.parametrize("backend", [None, "ref", "interpret"])
@pytest.mark.parametrize("C", [1, 2, 5])
def test_personalized_aggregate_matches_loop(backend, C):
    thetas = _random_thetas(C)
    rng = np.random.default_rng(3)
    W = rng.random((C, C)).astype(np.float32)
    np.fill_diagonal(W, 0)
    ref = personalized_aggregate(thetas, W, backend="loop")
    out = personalized_aggregate(thetas, W, backend=backend)
    assert len(out) == C
    for r, o in zip(ref, out):
        for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(o)):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_personalized_aggregate_row_subset():
    """The zero-row-skip path aggregates only the requested rows."""
    thetas = _random_thetas(4, seed=5)
    rng = np.random.default_rng(6)
    W = rng.random((4, 4)).astype(np.float32)
    np.fill_diagonal(W, 0)
    full = personalized_aggregate(thetas, W, backend="loop")
    sub = personalized_aggregate(thetas, W[[1, 3]], backend="interpret")
    assert len(sub) == 2
    for r, o in zip((full[1], full[3]), sub):
        for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(o)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_server_round_skips_zero_rows():
    """A client whose neighbours have no history gets an all-zero relevance
    row: the server must skip its base entirely (no NaNs, no wasted rows)."""
    from repro.core.edge_model import EdgeModelConfig
    from repro.core.fedstil import FedSTIL

    rng = np.random.default_rng(0)

    def upload(c):
        return {"theta": {"w": jnp.ones((2,)) * c},
                "task_feature": rng.standard_normal(4).astype(np.float32)}

    cfg = EdgeModelConfig(n_classes=8)
    s = FedSTIL(cfg, n_clients=3)
    # round 0: only client 0 uploads -> its neighbours have no history
    out = s.server_round(0, {0: upload(0)})
    assert out == {0: {}}
    assert not np.isnan(s.last_W).any()
    # later round: everyone uploads, every row is nonzero -> all get bases
    out2 = s.server_round(1, {c: upload(c) for c in range(3)})
    assert set(out2) == {0, 1, 2}
    assert all("B" in d for d in out2.values())
    for d in out2.values():
        assert not np.isnan(np.asarray(d["B"]["w"])).any()


def test_server_round_partial_participation_renormalizes():
    """When only a subset uploads, Eq. 6 must stay a convex combination of
    the neighbours that DID upload (not silently down-scaled by the absent
    clients' relevance mass)."""
    from repro.core.edge_model import EdgeModelConfig
    from repro.core.fedstil import FedSTIL

    rng = np.random.default_rng(2)

    def upload(c):
        return {"theta": {"w": jnp.ones((2,)) * (c + 1)},
                "task_feature": rng.standard_normal(4).astype(np.float32)}

    cfg = EdgeModelConfig(n_classes=8)
    s = FedSTIL(cfg, n_clients=3)
    s.server_round(0, {c: upload(c) for c in range(3)})   # seed histories
    # client 2 drops out: client 0's base must be exactly theta_1 (its only
    # participating neighbour), weight 1 after renormalization
    out = s.server_round(1, {0: upload(0), 1: upload(1)})
    np.testing.assert_allclose(np.asarray(out[0]["B"]["w"]), 2.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]["B"]["w"]), 1.0, atol=1e-5)
