"""Stacked (device-resident) engine regression tests:

  (a) the (C, k, D) ring-buffer history matches the host-list
      ``stacked_history()`` oracle across pushes, partial participation,
      and overflow past ``history_len``;
  (b) a FedSTIL simulation with ``engine="stacked"`` matches
      ``engine="host"`` metrics to tolerance (they draw identical
      minibatches by construction);
  (c) the fused normalize+mask aggregate kernel allcloses the
      ``backend="loop"`` reference, including all-zero rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedSTIL
from repro.core.aggregation import personalized_aggregate
from repro.core.edge_model import EdgeModelConfig
from repro.core.relevance import (DeviceRingHistory, RelevanceTracker,
                                  normalize_rows)
from repro.data import FederatedReIDBenchmark
from repro.federated import run_simulation
from repro.kernels import ops
from repro.lifelong import STL


# ---------------------------------------------------------------------------
# (a) ring buffer == host-list oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_rounds", [1, 3, 9])   # 9 > history_len: overflow
def test_ring_matches_host_oracle(n_rounds):
    rng = np.random.default_rng(0)
    C, k, D = 4, 4, 8
    tr = RelevanceTracker(C, history_len=k)
    ring = DeviceRingHistory(C, k, D)
    for r in range(n_rounds):
        feats = rng.standard_normal((C, D)).astype(np.float32)
        # partial participation after the first round
        mask = np.ones((C,), np.float32) if r == 0 else \
            (rng.random(C) < 0.6).astype(np.float32)
        for c in range(C):
            if mask[c] > 0:
                tr.push(c, feats[c])
        ring.push_all(feats, mask)
    dense, valid = tr.stacked_history()
    np.testing.assert_allclose(np.asarray(ring.buf), dense)
    np.testing.assert_allclose(np.asarray(ring.valid), valid)


def test_ring_empty_and_never_pushed_rows():
    ring = DeviceRingHistory(3, 2, 4)
    assert (np.asarray(ring.valid) == 0).all()
    feats = np.ones((3, 4), np.float32)
    ring.push_all(feats, np.array([1.0, 0.0, 0.0], np.float32))
    valid = np.asarray(ring.valid)
    assert valid[0, 0] == 1.0 and (valid[1:] == 0).all()
    W = np.asarray(ring.raw_relevance(forgetting_ratio=0.5))
    assert (W[1:] == 0).all()          # rows without a current feature


def test_tracker_push_all_keeps_ring_and_oracle_in_sync():
    """push_all updates the device ring AND the host lists; the batched
    relevance (ring-sourced) still matches the loop oracle."""
    rng = np.random.default_rng(2)
    C, k, D = 5, 3, 16
    tr = RelevanceTracker(C, history_len=k)
    for r in range(k + 2):             # overflow past history_len
        mask = np.ones((C,), np.float32) if r == 0 else \
            (rng.random(C) < 0.7).astype(np.float32)
        tr.push_all(rng.standard_normal((C, D)).astype(np.float32), mask)
    assert tr._ring is not None and not tr._ring_dirty
    np.testing.assert_allclose(tr.relevance(), tr.relevance(backend="loop"),
                               atol=1e-5)


def test_tracker_per_client_push_resyncs_ring():
    """Interleaving per-client push (dirty ring) with push_all must rebuild
    the ring from the oracle lists before going resident again."""
    rng = np.random.default_rng(3)
    C, k, D = 3, 3, 8
    tr = RelevanceTracker(C, history_len=k)
    tr.push_all(rng.standard_normal((C, D)).astype(np.float32))
    tr.push(1, rng.standard_normal(D).astype(np.float32))   # dirties ring
    assert tr._ring_dirty
    tr.push_all(rng.standard_normal((C, D)).astype(np.float32))
    dense, valid = tr.stacked_history()
    np.testing.assert_allclose(np.asarray(tr._ring.buf), dense)
    np.testing.assert_allclose(np.asarray(tr._ring.valid), valid)
    np.testing.assert_allclose(tr.relevance(), tr.relevance(backend="loop"),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# (b) stacked engine == host engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bench():
    return FederatedReIDBenchmark(n_clients=3, n_tasks=3, n_identities=60,
                                  ids_per_task=10, samples_per_id=8, seed=1)


@pytest.fixture(scope="module")
def cfg(bench):
    return EdgeModelConfig(n_classes=bench.n_classes)


def test_fedstil_stacked_matches_host(bench, cfg):
    host = run_simulation(FedSTIL(cfg, n_clients=3, epochs=2), bench,
                          rounds=4, eval_every=2)
    stacked = run_simulation(FedSTIL(cfg, n_clients=3, epochs=2), bench,
                             rounds=4, eval_every=2, engine="stacked")
    for key in ("mAP", "R1", "R5", "forgetting_mAP"):
        assert abs(host.final(key) - stacked.final(key)) < 1e-4, key
    # identical payloads -> identical byte accounting
    assert host.comm.total_c2s == stacked.comm.total_c2s
    assert host.comm.total_s2c == stacked.comm.total_s2c
    assert host.storage_bytes == stacked.storage_bytes


def test_stl_stacked_matches_host(bench, cfg):
    host = run_simulation(STL(cfg, epochs=2), bench, rounds=3, eval_every=3)
    stacked = run_simulation(STL(cfg, epochs=2), bench, rounds=3,
                             eval_every=3, engine="stacked")
    for key in ("mAP", "R1"):
        assert abs(host.final(key) - stacked.final(key)) < 1e-4, key
    assert stacked.comm.total == 0


def test_stacked_engine_rejects_host_only_strategy(bench, cfg):
    # FedCurv's per-upload Fisher estimation keeps it host-only
    from repro.federated import FedCurv
    with pytest.raises(ValueError, match="stacked"):
        run_simulation(FedCurv(cfg, epochs=2), bench, rounds=2,
                       engine="stacked")


@pytest.mark.parametrize("make", [
    lambda cfg: __import__("repro.federated", fromlist=["FedAvg"]
                           ).FedAvg(cfg, epochs=2),
    lambda cfg: __import__("repro.federated", fromlist=["FedProx"]
                           ).FedProx(cfg, epochs=2),
], ids=["fedavg", "fedprox"])
def test_mean_strategies_stacked_match_host(bench, cfg, make):
    host = run_simulation(make(cfg), bench, rounds=3, eval_every=3)
    stacked = run_simulation(make(cfg), bench, rounds=3, eval_every=3,
                             engine="stacked")
    for key in ("mAP", "R1"):
        assert abs(host.final(key) - stacked.final(key)) < 1e-4, key
    assert host.comm.total_c2s == stacked.comm.total_c2s
    assert host.comm.total_s2c == stacked.comm.total_s2c


def test_stacked_relevance_matrix_matches_host(bench, cfg):
    sh = FedSTIL(cfg, n_clients=3, epochs=2)
    ss = FedSTIL(cfg, n_clients=3, epochs=2)
    run_simulation(sh, bench, rounds=3, eval_every=3)
    run_simulation(ss, bench, rounds=3, eval_every=3, engine="stacked")
    assert ss.last_W is not None and ss.last_W.shape == (3, 3)
    np.testing.assert_allclose(ss.last_W, sh.last_W, atol=1e-4)
    assert np.allclose(np.diag(ss.last_W), 0.0)


# ---------------------------------------------------------------------------
# (c) fused normalize+mask aggregate kernel == loop reference
# ---------------------------------------------------------------------------


def _loop_reference(w, thetas_mat):
    """normalize_rows + the per-leaf loop aggregate, the PR-1 oracle path."""
    C = w.shape[0]
    wm = np.asarray(w, np.float32) * (1.0 - np.eye(C, dtype=np.float32))
    wn = normalize_rows(wm)
    thetas = [{"t": jnp.asarray(thetas_mat[c])} for c in range(C)]
    bases = personalized_aggregate(thetas, wn, backend="loop")
    return np.stack([np.asarray(b["t"]) for b in bases]), wn


@pytest.mark.parametrize("backend", [None, "ref", "interpret"])
@pytest.mark.parametrize("C", [2, 5])
def test_fused_aggregate_matches_loop(backend, C):
    rng = np.random.default_rng(7)
    w = rng.random((C, C)).astype(np.float32)   # junk on the diagonal
    thetas = rng.standard_normal((C, 300)).astype(np.float32)
    B_ref, Wn_ref = _loop_reference(w, thetas)
    B, Wn = ops.fused_relevance_aggregate(jnp.asarray(w),
                                          jnp.asarray(thetas),
                                          backend=backend)
    np.testing.assert_allclose(np.asarray(Wn), Wn_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(B), B_ref, atol=1e-4)


@pytest.mark.parametrize("backend", [None, "interpret"])
def test_fused_aggregate_all_zero_rows(backend):
    """Zero-relevance rows must stay zero — no NaNs from 0/0."""
    rng = np.random.default_rng(8)
    w = rng.random((4, 4)).astype(np.float32)
    w[1] = 0.0                                   # isolated client
    w[3] = 0.0
    thetas = rng.standard_normal((4, 257)).astype(np.float32)
    B, Wn = ops.fused_relevance_aggregate(jnp.asarray(w),
                                          jnp.asarray(thetas),
                                          backend=backend)
    B, Wn = np.asarray(B), np.asarray(Wn)
    assert not np.isnan(B).any() and not np.isnan(Wn).any()
    assert (Wn[1] == 0).all() and (B[1] == 0).all()
    assert (Wn[3] == 0).all() and (B[3] == 0).all()
    B_ref, Wn_ref = _loop_reference(w, thetas)
    np.testing.assert_allclose(Wn, Wn_ref, atol=1e-5)
    np.testing.assert_allclose(B, B_ref, atol=1e-4)


def test_fused_aggregate_fully_zero_w():
    w = jnp.zeros((3, 3))
    thetas = jnp.ones((3, 130))
    B, Wn = ops.fused_relevance_aggregate(w, thetas, backend="interpret")
    assert (np.asarray(B) == 0).all() and (np.asarray(Wn) == 0).all()


# ---------------------------------------------------------------------------
# sharded path (single-device mesh exercises the program + specs)
# ---------------------------------------------------------------------------


def test_sharded_fused_aggregate_matches_kernel():
    from repro.core.fedstil import sharded_fused_aggregate

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.random((8, 8)).astype(np.float32))
    thetas = jnp.asarray(rng.standard_normal((8, 512)).astype(np.float32))
    B, Wn = sharded_fused_aggregate(w, thetas, mesh)
    B_ref, Wn_ref = ops.fused_relevance_aggregate(w, thetas, backend="ref")
    np.testing.assert_allclose(np.asarray(Wn), np.asarray(Wn_ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(B), np.asarray(B_ref), atol=1e-5)
