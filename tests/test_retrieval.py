"""ReID retrieval metric correctness (mAP / CMC)."""
import numpy as np

from repro.evalreid import distance_matrix, evaluate_retrieval, l2_normalize


def test_distance_matrix_identity():
    x = np.random.default_rng(0).standard_normal((5, 8)).astype(np.float32)
    d = distance_matrix(x, x)
    assert np.allclose(np.diag(d), 0, atol=1e-5)
    assert (d >= -1e-5).all()


def test_map_hand_case():
    # 1 query, gallery ranks: [match, miss, match] -> AP = (1/1 + 2/3)/2
    qf = np.array([[1.0, 0.0]])
    gf = np.array([[1.0, 0.0], [0.8, 0.6], [0.5, 0.866]])
    qid = np.array([7])
    gid = np.array([7, 3, 7])
    m = evaluate_retrieval(qf, qid, gf, gid)
    expected_ap = (1.0 + 2.0 / 3.0) / 2.0
    assert abs(m["mAP"] - expected_ap) < 1e-6
    assert m["R1"] == 1.0


def test_cmc_ranks():
    qf = np.array([[0.0, 1.0]])
    gf = np.array([[1.0, 0.0], [0.9, 0.4], [0.0, 0.95]])
    qid = np.array([1])
    gid = np.array([2, 1, 3])   # correct match ranked 2nd
    m = evaluate_retrieval(qf, qid, gf, gid, ranks=(1, 3, 5))
    assert m["R1"] == 0.0 and m["R3"] == 1.0
