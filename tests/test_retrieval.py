"""ReID retrieval metric correctness (mAP / CMC)."""
import numpy as np

from repro.evalreid import distance_matrix, evaluate_retrieval


def test_distance_matrix_identity():
    x = np.random.default_rng(0).standard_normal((5, 8)).astype(np.float32)
    d = distance_matrix(x, x)
    assert np.allclose(np.diag(d), 0, atol=1e-5)
    assert (d >= -1e-5).all()


def test_map_hand_case():
    # 1 query, gallery ranks: [match, miss, match] -> AP = (1/1 + 2/3)/2
    qf = np.array([[1.0, 0.0]])
    gf = np.array([[1.0, 0.0], [0.8, 0.6], [0.5, 0.866]])
    qid = np.array([7])
    gid = np.array([7, 3, 7])
    m = evaluate_retrieval(qf, qid, gf, gid)
    expected_ap = (1.0 + 2.0 / 3.0) / 2.0
    assert abs(m["mAP"] - expected_ap) < 1e-6
    assert m["R1"] == 1.0


def test_cmc_ranks():
    qf = np.array([[0.0, 1.0]])
    gf = np.array([[1.0, 0.0], [0.9, 0.4], [0.0, 0.95]])
    qid = np.array([1])
    gid = np.array([2, 1, 3])   # correct match ranked 2nd
    m = evaluate_retrieval(qf, qid, gf, gid, ranks=(1, 3, 5))
    assert m["R1"] == 0.0 and m["R3"] == 1.0


def test_distance_ties_resolve_by_gallery_order():
    """Stable sort: exactly tied gallery rows rank in index order."""
    qf = np.array([[1.0, 0.0]])
    gf = np.array([[1.0, 0.0], [1.0, 0.0]])   # identical rows: exact tie
    m = evaluate_retrieval(qf, np.array([7]), gf, np.array([3, 7]))
    # non-match (id 3) is earlier in the gallery, so it wins the tie:
    # the match sits at rank 2 -> AP = 1/2, R1 = 0, R3 = 1
    assert abs(m["mAP"] - 0.5) < 1e-6
    assert m["R1"] == 0.0 and m["R3"] == 1.0


def test_query_without_cross_camera_match_is_excluded():
    """A query whose id never appears in the gallery is dropped from every
    average (not scored 0)."""
    qf = np.array([[1.0, 0.0], [0.0, 1.0]])
    gf = np.array([[1.0, 0.0], [0.6, 0.8]])
    m = evaluate_retrieval(qf, np.array([7, 9]), gf, np.array([7, 3]))
    # only query 0 counts; its match is rank 1
    assert m["mAP"] == 1.0 and m["R1"] == 1.0


def test_all_invalid_query_set_scores_zero():
    qf = np.array([[1.0, 0.0], [0.0, 1.0]])
    gf = np.array([[1.0, 0.0]])
    m = evaluate_retrieval(qf, np.array([9, 8]), gf, np.array([3]))
    assert m["mAP"] == 0.0 and m["R1"] == 0.0 and m["R5"] == 0.0
