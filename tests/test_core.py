"""Unit tests for the FedSTIL core (paper equations 2-6, rehearsal, tying)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PrototypeMemory,
    RelevanceTracker,
    combine,
    init_adaptive,
    kl_similarity,
    pairwise_similarity,
    personalized_aggregate,
    fedavg_aggregate,
    tying_loss,
)
from repro.core.similarity import cosine_similarity, euclidean_similarity


def test_adaptive_combine_eq2():
    B = {"w": jnp.array([1.0, 2.0]), "b": jnp.array([[1.0, -1.0]])}
    al = {"w": jnp.array([0.5, 0.0]), "b": jnp.array([[2.0, 2.0]])}
    A = {"w": jnp.array([0.1, 0.1]), "b": jnp.array([[0.0, 1.0]])}
    th = combine(B, al, A)
    np.testing.assert_allclose(th["w"], [0.6, 0.1])
    np.testing.assert_allclose(th["b"], [[2.0, -1.0]])


def test_init_adaptive_identity():
    theta0 = {"w": jnp.arange(6.0).reshape(2, 3)}
    ad = init_adaptive(theta0)
    np.testing.assert_allclose(ad.theta()["w"], theta0["w"])


def test_similarities_basic():
    a = jnp.array([1.0, 2.0, 3.0])
    for fn in (kl_similarity, cosine_similarity, euclidean_similarity):
        s_self = float(fn(a, a))
        assert s_self == pytest.approx(1.0, abs=1e-5)
        b = jnp.array([-3.0, 5.0, 0.1])
        s = float(fn(a, b))
        assert 0.0 <= s <= 1.0 + 1e-6
        assert s < s_self


def test_pairwise_similarity_shape():
    fa = jnp.ones((3, 8))
    fb = jnp.zeros((4, 8))
    S = pairwise_similarity(fa, fb, "kl")
    assert S.shape == (3, 4)


def test_relevance_decay_and_normalization():
    tr = RelevanceTracker(n_clients=3, history_len=4, forgetting_ratio=0.5)
    rng = np.random.default_rng(0)
    # client 1's history matches client 0's current task; client 2 differs
    base = rng.standard_normal(16).astype(np.float32)
    for t in range(3):
        tr.push(0, base + 0.01 * rng.standard_normal(16))
        tr.push(1, base + 0.01 * rng.standard_normal(16))
        tr.push(2, 10 * rng.standard_normal(16))
    W = tr.relevance()
    assert W.shape == (3, 3)
    assert np.allclose(np.diag(W), 0)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-5)
    assert W[0, 1] > W[0, 2]   # similar neighbour gets more weight


def test_personalized_aggregate_onehot():
    thetas = [{"w": jnp.full((2, 2), float(i))} for i in range(3)]
    W = np.array([[0, 1, 0], [0, 0, 1], [1, 0, 0]], np.float32)
    out = personalized_aggregate(thetas, W)
    np.testing.assert_allclose(out[0]["w"], 1.0)
    np.testing.assert_allclose(out[1]["w"], 2.0)
    np.testing.assert_allclose(out[2]["w"], 0.0)


def test_fedavg_aggregate_mean():
    thetas = [{"w": jnp.full((2,), float(i))} for i in range(4)]
    out = fedavg_aggregate(thetas)
    np.testing.assert_allclose(out["w"], 1.5)


def test_rehearsal_memory_nearest_mean_and_capacity():
    mem = PrototypeMemory(capacity=20, per_identity=2)
    rng = np.random.default_rng(0)
    for task in range(5):
        protos = rng.standard_normal((30, 8)).astype(np.float32)
        labels = np.repeat(np.arange(3) + 10 * task, 10)
        outputs = protos.copy()    # identity adaptive layer
        mem.add_task(protos, labels, outputs, task_id=task)
        assert len(mem) <= 20
    # per-identity cap respected at insert time
    mem2 = PrototypeMemory(capacity=100, per_identity=2)
    protos = rng.standard_normal((10, 4)).astype(np.float32)
    labels = np.zeros(10, np.int64)
    mem2.add_task(protos, labels, protos, task_id=0)
    assert len(mem2) == 2
    # stored exemplars are the nearest to the mean
    center = protos.mean(0)
    d = np.linalg.norm(protos - center, axis=1)
    expected = set(np.argsort(d)[:2].tolist())
    got = {int(np.nonzero((protos == p).all(1))[0][0]) for p in mem2.protos}
    assert got == expected


def test_rehearsal_sample():
    mem = PrototypeMemory(capacity=50, per_identity=5)
    rng = np.random.default_rng(1)
    protos = rng.standard_normal((40, 6)).astype(np.float32)
    labels = np.repeat(np.arange(4), 10)
    mem.add_task(protos, labels, protos, task_id=0)
    out = mem.sample(rng, 8)
    assert out is not None
    x, y = out
    assert len(x) == 8 and len(y) == 8


def test_tying_loss():
    th = {"w": jnp.array([1.0, 2.0])}
    prev = {"w": jnp.array([1.0, 1.0])}
    assert float(tying_loss(th, prev, lam_l1=1.0)) == pytest.approx(1.0)
    assert float(tying_loss(th, th, lam_l1=1.0)) == 0.0
