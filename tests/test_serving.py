"""Serving-path guarantees (repro.serving):

  * kernel parity: the ``batched_int8_pairwise_dist`` dispatcher's Pallas
    interpret path vs the jnp ref, and ref vs manual dequant + the fp32
    batched distance oracle;
  * index-refresh parity: the jitted refresh program vs its numpy host
    oracle (int8 codes bit-exact on CPU, dequantized rows allclose);
  * exact rank parity: the fp32 serving program returns the numpy
    retrieval oracle's ids verbatim (stable-tie order included);
  * int8 fidelity: mAP delta vs fp32 bounded on the synthetic bench;
  * batch-composition invariance (the frozen-BN contract the continuous
    batcher relies on), batcher coalescing, and incremental head updates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import edge_model as EM
from repro.kernels import ops
from repro.kernels import ref as REF
from repro.serving import (ContinuousBatcher, GalleryIndex, RetrievalEngine,
                           map_from_ranked_ids)
from repro.serving.index import refresh_host

CFG = EM.EdgeModelConfig()


def _stack_thetas(C, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), C)
    thetas = [EM.init_adaptive_layers(k, CFG) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *thetas)


def _mk_index(C=3, G=40, seed=0, ragged=True, keep_fp32=True):
    rng = np.random.default_rng(seed)
    sizes = [G - 5 * c if ragged else G for c in range(C)]
    protos = [rng.standard_normal((n, CFG.proto_dim)).astype(np.float32)
              for n in sizes]
    ids = [rng.integers(0, 12, n).astype(np.int32) for n in sizes]
    return GalleryIndex(protos, ids, capacity=G, keep_fp32=keep_fp32), rng


@pytest.fixture(scope="module")
def engines():
    index, rng = _mk_index()
    theta = _stack_thetas(index.n_clients)
    eng8 = RetrievalEngine(index, theta, k=5, mode="int8")
    engf = RetrievalEngine(index, theta, k=5, mode="fp32")
    return index, theta, eng8, engf, rng


@pytest.mark.parametrize("C,B,G,F", [(3, 4, 40, 64), (2, 16, 300, 64),
                                     (1, 1, 7, 32)])
def test_batched_int8_pairwise_dist_parity(C, B, G, F):
    """Dispatcher ref vs interpret vs dequant+fp32-dist oracle."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    q = jax.random.normal(k1, (C, B, F), jnp.float32)
    g = jax.random.normal(k2, (C, G, F), jnp.float32)
    gq, scales = ops.batched_quantize(g.reshape(C, G * F), chunk=F,
                                      backend="ref")
    gq = gq.reshape(C, G, F)
    gdeq = gq.astype(jnp.float32) * scales[..., None]
    gn2 = jnp.sum(jnp.square(gdeq), -1)
    d_ref = ops.batched_int8_pairwise_dist(q, gq, scales, gn2, backend="ref")
    d_int = ops.batched_int8_pairwise_dist(q, gq, scales, gn2,
                                           backend="interpret")
    d_ora = REF.batched_pairwise_dist_ref(q, gdeq)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_int),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_ora),
                               atol=1e-4, rtol=1e-4)


def test_index_refresh_matches_host_oracle(engines):
    index, theta, _, _, _ = engines
    gmask = (index.gids_host >= 0).astype(np.float32)
    hq, hs, hn2, hmu, hsd, hf = refresh_host(theta, index.gp, gmask)
    np.testing.assert_array_equal(hq, np.asarray(index.gq))
    np.testing.assert_allclose(hs, np.asarray(index.gscale), rtol=1e-6)
    np.testing.assert_allclose(hn2, np.asarray(index.gn2), atol=1e-5)
    np.testing.assert_allclose(hmu, np.asarray(index.bn_mu), atol=1e-5)
    np.testing.assert_allclose(hsd, np.asarray(index.bn_sd), atol=1e-5)
    np.testing.assert_allclose(hf, np.asarray(index.gf), atol=1e-5)
    # empty slots: zero codes, unit scale, zero norm
    empty = np.asarray(index.gids) < 0
    assert np.all(np.asarray(index.gq)[empty] == 0)
    assert np.all(np.asarray(index.gscale)[empty] == 1.0)
    assert np.all(np.asarray(index.gn2)[empty] == 0.0)


def test_fp32_rank_parity_exact(engines):
    """The fp32 serving program == numpy retrieval oracle, id for id."""
    _, _, _, engf, rng = engines
    C = engf.index.n_clients
    qp = rng.standard_normal((C, 7, CFG.proto_dim)).astype(np.float32)
    qmask = np.ones((C, 7), np.float32)
    qmask[0, 5:] = 0.0                       # padded slots must come back -1
    ids_d, dist_d = engf.query_batch(qp, qmask)
    ids_h, dist_h = engf.query_host(qp, qmask)
    np.testing.assert_array_equal(ids_d, ids_h)
    np.testing.assert_allclose(dist_d[qmask > 0], dist_h[qmask > 0],
                               atol=1e-5)
    assert np.all(ids_d[0, 5:] == -1)


def test_int8_close_to_fp32(engines):
    """Quantization moves distances by O(1/127) — top-1 must agree on
    well-separated synthetic data, distances allclose at lsb tolerance."""
    _, _, eng8, engf, rng = engines
    C = engf.index.n_clients
    qp = rng.standard_normal((C, 6, CFG.proto_dim)).astype(np.float32)
    qmask = np.ones((C, 6), np.float32)
    ids8, d8 = eng8.query_batch(qp, qmask)
    idsf, df = engf.query_batch(qp, qmask)
    assert (ids8[..., 0] == idsf[..., 0]).mean() >= 0.9
    np.testing.assert_allclose(d8, df, atol=0.05)


def test_int8_map_delta_bounded():
    """Tier-1 fidelity bound: full-ranking mAP, int8 vs fp32, on galleries
    with real id structure (repeated ids -> multiple matches/query)."""
    index, rng = _mk_index(C=4, G=60, seed=3)
    theta = _stack_thetas(4, seed=3)
    eng8 = RetrievalEngine(index, theta, mode="int8")
    engf = RetrievalEngine(index, theta, mode="fp32")
    G = index.capacity
    qp = rng.standard_normal((4, 10, CFG.proto_dim)).astype(np.float32)
    qmask = np.ones((4, 10), np.float32)
    qids = rng.integers(0, 12, (4, 10))
    ids8, _ = eng8.query_batch(qp, qmask, k=G)
    idsf, _ = engf.query_batch(qp, qmask, k=G)
    m8 = np.mean([map_from_ranked_ids(ids8[c], qids[c]) for c in range(4)])
    mf = np.mean([map_from_ranked_ids(idsf[c], qids[c]) for c in range(4)])
    assert mf > 0.0
    assert abs(m8 - mf) <= 0.01, f"int8 mAP delta {abs(m8 - mf):.4f}"


def test_batch_composition_invariance(engines):
    """Frozen BN stats: a query's answer is identical no matter which
    batch it is coalesced into (ids exact; distances to ulp — XLA's GEMM
    reduction order varies with the batch shape)."""
    _, _, eng8, _, rng = engines
    C = eng8.index.n_clients
    probe = rng.standard_normal(CFG.proto_dim).astype(np.float32)
    qp1 = np.zeros((C, 1, CFG.proto_dim), np.float32)
    qp1[1, 0] = probe
    m1 = np.zeros((C, 1), np.float32)
    m1[1, 0] = 1.0
    ids1, d1 = eng8.query_batch(qp1, m1)
    qp8 = rng.standard_normal((C, 8, CFG.proto_dim)).astype(np.float32)
    qp8[1, 3] = probe
    m8 = np.ones((C, 8), np.float32)
    ids8, d8 = eng8.query_batch(qp8, m8)
    np.testing.assert_array_equal(ids1[1, 0], ids8[1, 3])
    np.testing.assert_allclose(d1[1, 0], d8[1, 3], atol=1e-5)


def test_update_swaps_head(engines):
    """engine.update(new theta) == building a fresh engine from scratch
    (incremental refresh is exact), and actually changes the index."""
    index, theta, _, _, rng = engines
    C = index.n_clients
    eng = RetrievalEngine(_mk_index()[0], theta, k=5, mode="int8")
    old_gq = np.asarray(eng.index.gq).copy()
    theta2 = _stack_thetas(C, seed=9)
    eng.update(theta2)
    assert not np.array_equal(old_gq, np.asarray(eng.index.gq))
    fresh = RetrievalEngine(_mk_index()[0], theta2, k=5, mode="int8")
    np.testing.assert_array_equal(np.asarray(eng.index.gq),
                                  np.asarray(fresh.index.gq))
    qp = rng.standard_normal((C, 3, CFG.proto_dim)).astype(np.float32)
    qmask = np.ones((C, 3), np.float32)
    np.testing.assert_array_equal(eng.query_batch(qp, qmask)[0],
                                  fresh.query_batch(qp, qmask)[0])


def test_extend_appends_rows():
    # leave headroom, then extend client 0 with fresh rows under new ids
    small, rng = _mk_index(C=2, G=20, ragged=False)
    theta = _stack_thetas(2)
    small.gids_host[:, 15:] = -1             # simulate 15/20 fill
    small._fill[:] = 15
    eng = RetrievalEngine(small, theta, k=3, mode="fp32")
    new_p = rng.standard_normal((4, CFG.proto_dim)).astype(np.float32)
    eng.extend(0, new_p, np.full(4, 99, np.int32))
    assert small.fill[0] == 19
    # the new rows are retrievable: query WITH one of them
    qp = np.zeros((2, 1, CFG.proto_dim), np.float32)
    qp[0, 0] = new_p[2]
    ids, _ = eng.query_batch(qp, np.ones((2, 1), np.float32))
    assert 99 in ids[0, 0]
    with pytest.raises(ValueError):
        eng.extend(0, rng.standard_normal((5, CFG.proto_dim)), np.arange(5))


def test_batcher_coalesces_and_matches_direct(engines):
    """Tickets drain oldest-first in <= ceil(n/B) steps per client and
    return exactly what a direct query_batch returns."""
    _, _, eng8, _, rng = engines
    C = eng8.index.n_clients
    b = ContinuousBatcher(eng8, batch=4)
    protos = rng.standard_normal((9, CFG.proto_dim)).astype(np.float32)
    tickets = [b.submit(1, protos[i], qid=i) for i in range(9)]
    assert b.pending == 9
    first = b.step()
    assert len(first) == 4 and [t.qid for t in first] == [0, 1, 2, 3]
    rest = b.drain()
    assert len(rest) == 5 and b.pending == 0
    # per-ticket results == the fixed-shape direct call
    qp = np.zeros((C, 1, CFG.proto_dim), np.float32)
    for t, p in zip(tickets, protos):
        qp[1, 0] = p
        m = np.zeros((C, 1), np.float32)
        m[1, 0] = 1.0
        ids, _ = eng8.query_batch(qp, m)
        np.testing.assert_array_equal(t.ids, ids[1, 0])
        assert t.t_done >= t.t_submit


def test_map_from_ranked_ids_semantics():
    # matches at ranks 1 and 3: AP = (1/1 + 2/3)/2
    ids = np.array([[7, 2, 7, 3], [1, 2, 3, 4]])
    assert map_from_ranked_ids(ids, np.array([7, 9])) == pytest.approx(5 / 6)
    # masked-out query dropped even if it would match
    assert map_from_ranked_ids(ids, np.array([7, 1]),
                               qmask=np.array([1.0, 0.0])) == pytest.approx(5 / 6)
