"""End-to-end behaviour tests for the full system."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import synthetic_lm_batch
from repro.train import init_train_state, make_train_step
from repro.train.optimizer import adam


def test_lm_training_reduces_loss():
    """FedSTIL-split training (frozen trunk, adaptive B⊙alpha+A) learns on
    structured synthetic tokens."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    st = init_train_state(cfg, jax.random.PRNGKey(0),
                          optimizer=adam(lr=3e-3))
    step = jax.jit(make_train_step(cfg, optimizer=adam(lr=3e-3)))
    rng = np.random.default_rng(0)
    losses = []
    tr, opt = st.trainable, st.opt_state
    for i in range(30):
        toks, labels = synthetic_lm_batch(rng, 8, 32, cfg.vocab_size)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        tr, opt, m = step(st.frozen, st.B, tr, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_paper_pipeline_end_to_end():
    """Full FedSTIL round-trip on the synthetic ReID benchmark."""
    from repro.core import FedSTIL
    from repro.core.edge_model import EdgeModelConfig
    from repro.data import FederatedReIDBenchmark
    from repro.federated import run_simulation

    bench = FederatedReIDBenchmark(n_clients=3, n_tasks=2, n_identities=40,
                                   ids_per_task=8, samples_per_id=6, seed=0)
    cfg = EdgeModelConfig(n_classes=bench.n_classes)
    res = run_simulation(FedSTIL(cfg, n_clients=3, epochs=2), bench,
                         rounds=4, eval_every=2)
    assert len(res.rounds) >= 2
    assert res.rounds[-1]["mAP"] > 0.2
    assert res.comm.total_c2s > 0 and res.comm.total_s2c > 0
    assert res.storage_bytes > 0


@pytest.mark.slow
def test_debug_mesh_sharding_subprocess():
    """Sharded-vs-unsharded equivalence on an 8-device debug mesh (separate
    process because device count locks at first jax init)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, functools
from repro.common.compat import set_mesh
from repro.configs import get_config
from repro.launch import steps as STEPS
from repro.launch.mesh import make_debug_mesh
from repro.configs.base import ShapeConfig
from repro.train import trainer as TR

cfg = get_config("qwen3-1.7b").reduced()
mesh = make_debug_mesh(tp=2, dp=2)
shape = ShapeConfig("t", 32, 4, "train")
fn, _, _ = STEPS.build_train_step(cfg, mesh, shape, multi_pod=False)
st = TR.init_train_state(cfg, jax.random.PRNGKey(0), tp=2)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}
with set_mesh(mesh):
    tr, opt, metrics = fn(st.frozen, st.B, st.trainable, st.opt_state, batch)
step0 = TR.make_train_step(cfg, tie_lambda=1e-4)
tr0, opt0, m0 = step0(st.frozen, st.B, st.trainable, st.opt_state, batch)
assert abs(float(metrics["loss"]) - float(m0["loss"])) < 2e-3, (
    float(metrics["loss"]), float(m0["loss"]))
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stderr[-2000:]


@pytest.mark.slow
def test_fed_round_on_mesh_matches_numpy_server():
    """The on-mesh FedSTIL round (Eq. 4-6 as collectives) == numpy server."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.fed_round", "--demo"],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "W, B match" in r.stdout, r.stderr[-2000:]
