"""Batched (C clients x T tasks) retrieval eval regression tests:

  (a) ``evaluate_retrieval_batched(backend="device")`` allcloses the numpy
      per-(c, t) oracle (``backend="host"``) across random problems,
      padding masks, exact distance ties, queries with no cross-camera
      match, and all-invalid query sets — for both kernel backends;
  (b) gallery prototypes assembled from the pre-extracted query prototypes
      (the per-(c, t) cache) match re-extracting the raw gallery;
  (c) ``run_simulation(eval_backend="device")`` matches
      ``eval_backend="host"`` tracker metrics on both engines;
  (d) the mesh-sharded eval round matches the single-device program;
  (e) CommLog batched logging equals the per-client loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.accounting import CommLog
from repro.core import FedSTIL
from repro.core import edge_model as EM
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.evalreid import evaluate_retrieval_batched
from repro.evalreid.batched import max_match_bound
from repro.federated import run_simulation


def _random_problem(rng, C=3, T=2, Q=6, G=40, F=8, n_ids=12):
    qf = rng.standard_normal((C, T, Q, F)).astype(np.float32)
    gf = rng.standard_normal((C, G, F)).astype(np.float32)
    qids = rng.integers(0, n_ids, (C, T, Q)).astype(np.int32)
    gids = rng.integers(0, n_ids, (C, G)).astype(np.int32)
    return qf, qids, gf, gids


def _assert_close(a, b):
    for k in a:
        np.testing.assert_allclose(a[k], b[k], atol=1e-5, err_msg=k)


@pytest.mark.parametrize("kernel_backend", [None, "interpret"])
def test_device_matches_oracle_random(kernel_backend):
    rng = np.random.default_rng(0)
    qf, qids, gf, gids = _random_problem(rng)
    host = evaluate_retrieval_batched(qf, qids, gf, gids, backend="host")
    dev = evaluate_retrieval_batched(qf, qids, gf, gids, backend="device",
                                     kernel_backend=kernel_backend)
    _assert_close(host, dev)


@pytest.mark.parametrize("max_matches", [None, 64])
def test_padding_masks(max_matches):
    """Padded queries/gallery rows must be invisible: one fully-masked
    task, one fully-masked gallery, and random partial masks."""
    rng = np.random.default_rng(1)
    qf, qids, gf, gids = _random_problem(rng, C=4, T=3, Q=5, G=30)
    qmask = (rng.random((4, 3, 5)) < 0.7).astype(np.float32)
    gmask = (rng.random((4, 30)) < 0.8).astype(np.float32)
    qmask[1, 2] = 0.0                       # fully padded task
    gmask[2] = 0.0                          # fully padded gallery
    host = evaluate_retrieval_batched(qf, qids, gf, gids, qmask=qmask,
                                      gmask=gmask, backend="host")
    dev = evaluate_retrieval_batched(qf, qids, gf, gids, qmask=qmask,
                                     gmask=gmask, backend="device",
                                     max_matches=max_matches)
    _assert_close(host, dev)
    assert (host["mAP"][1, 2] == 0.0) and (dev["mAP"][1, 2] == 0.0)
    assert (host["mAP"][2] == 0.0).all() and (dev["mAP"][2] == 0.0).all()


def test_distance_ties():
    """Exactly duplicated gallery rows: both paths break the tie by
    gallery order (stable sort == counting rule)."""
    qf = np.zeros((1, 1, 1, 2), np.float32)
    qf[0, 0, 0] = [1.0, 0.0]
    gf = np.zeros((1, 4, 2), np.float32)
    gf[0] = [[1.0, 0.0], [1.0, 0.0], [1.0, 0.0], [0.0, 1.0]]
    qids = np.array([[[7]]], np.int32)
    gids = np.array([[3, 7, 7, 5]], np.int32)   # ties: non-match first
    host = evaluate_retrieval_batched(qf, qids, gf, gids, backend="host")
    dev = evaluate_retrieval_batched(qf, qids, gf, gids, backend="device")
    _assert_close(host, dev)
    # matches at stable ranks 2, 3 -> AP = (1/2 + 2/3) / 2
    np.testing.assert_allclose(dev["mAP"][0, 0], (0.5 + 2 / 3) / 2,
                               atol=1e-6)
    assert dev["R1"][0, 0] == 0.0 and dev["R3"][0, 0] == 1.0


def test_no_cross_camera_match_excluded():
    """A query whose id never appears in its gallery is dropped from the
    averages by both paths (not scored 0)."""
    rng = np.random.default_rng(2)
    qf, qids, gf, gids = _random_problem(rng, C=2, T=1, Q=4, G=20, n_ids=6)
    qids[0, 0, 1] = 99                      # no such gallery id
    host = evaluate_retrieval_batched(qf, qids, gf, gids, backend="host")
    dev = evaluate_retrieval_batched(qf, qids, gf, gids, backend="device")
    _assert_close(host, dev)


def test_all_invalid_query_set_scores_zero():
    rng = np.random.default_rng(3)
    qf, qids, gf, gids = _random_problem(rng, C=2, T=1, Q=3, G=10, n_ids=4)
    qids[1, 0] = [50, 51, 52]               # none present in the gallery
    host = evaluate_retrieval_batched(qf, qids, gf, gids, backend="host")
    dev = evaluate_retrieval_batched(qf, qids, gf, gids, backend="device")
    _assert_close(host, dev)
    for k in ("mAP", "R1", "R5"):
        assert host[k][1, 0] == 0.0 and dev[k][1, 0] == 0.0


def test_max_match_bound_is_safe():
    """The tight bound gives the same result as the exhaustive M = G."""
    rng = np.random.default_rng(4)
    qf, qids, gf, gids = _random_problem(rng, C=2, T=2, Q=5, G=25, n_ids=5)
    bound = max_match_bound(qids, gids)
    exact = evaluate_retrieval_batched(qf, qids, gf, gids, backend="device",
                                       max_matches=gf.shape[1])
    tight = evaluate_retrieval_batched(qf, qids, gf, gids, backend="device",
                                       max_matches=bound)
    _assert_close(exact, tight)


# ---------------------------------------------------------------------------
# (b) gallery prototype cache == re-extraction
# ---------------------------------------------------------------------------


def test_gallery_prototype_cache_matches_extraction():
    from repro.federated.simulation import (_EvalCache,
                                            _pre_extract_prototypes)
    bench = FederatedReIDBenchmark(n_clients=3, n_tasks=2, n_identities=40,
                                   ids_per_task=8, samples_per_id=6, seed=0)
    cfg = EdgeModelConfig(n_classes=bench.n_classes)
    g_params = EM.init_extraction(jax.random.PRNGKey(0), cfg)
    protos = _pre_extract_prototypes(bench, g_params)
    cache = _EvalCache(bench, protos)
    for c in range(3):
        gal_x, gal_y = bench.gallery(c, 1)
        gal_p = np.asarray(EM.extract_prototypes(g_params, gal_x))
        p, y = cache.host_gallery(c, 1)
        np.testing.assert_array_equal(y, gal_y)
        np.testing.assert_allclose(p, gal_p, atol=1e-6)
    gp, gids, gmask = cache.device_gallery(1)
    assert (np.asarray(gmask) == 1.0).all()     # t = T-1: no padding
    p0, y0 = cache.host_gallery(0, 1)
    np.testing.assert_allclose(np.asarray(gp)[0], p0, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(gids)[0], y0)


# ---------------------------------------------------------------------------
# (c) simulation: device eval == host eval, both engines
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bench():
    return FederatedReIDBenchmark(n_clients=3, n_tasks=2, n_identities=40,
                                  ids_per_task=8, samples_per_id=6, seed=0)


@pytest.fixture(scope="module")
def cfg(bench):
    return EdgeModelConfig(n_classes=bench.n_classes)


@pytest.mark.parametrize("engine", ["host", "stacked"])
def test_simulation_device_eval_matches_host_eval(bench, cfg, engine):
    dev = run_simulation(FedSTIL(cfg, n_clients=3, epochs=2), bench,
                         rounds=4, eval_every=2, engine=engine,
                         eval_backend="device")
    host = run_simulation(FedSTIL(cfg, n_clients=3, epochs=2), bench,
                          rounds=4, eval_every=2, engine=engine,
                          eval_backend="host")
    for key in ("mAP", "R1", "R3", "R5", "forgetting_mAP"):
        assert abs(dev.final(key) - host.final(key)) < 2e-3, key
    assert dev.comm.total_c2s == host.comm.total_c2s
    assert dev.comm.total_s2c == host.comm.total_s2c


def test_simulation_rejects_unknown_eval_backend(bench, cfg):
    with pytest.raises(ValueError, match="eval_backend"):
        run_simulation(FedSTIL(cfg, n_clients=3, epochs=1), bench,
                       rounds=1, eval_backend="gpu")


# ---------------------------------------------------------------------------
# (d) mesh-sharded eval round
# ---------------------------------------------------------------------------


def test_sharded_eval_round_matches_device_program():
    from repro.federated.base import sharded_eval_fn, stacked_eval_program

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = EdgeModelConfig()
    rng = np.random.default_rng(5)
    C, T, Q, G = 4, 2, 6, 30
    theta = jax.vmap(lambda k: EM.init_adaptive_layers(k, cfg))(
        jax.random.split(jax.random.PRNGKey(1), C))
    qp = jnp.asarray(rng.standard_normal((C, T, Q, cfg.proto_dim)),
                     jnp.float32)
    qids = jnp.asarray(rng.integers(0, 10, (C, T, Q)), jnp.int32)
    tmask = jnp.ones((C, T), jnp.float32)
    gp = jnp.asarray(rng.standard_normal((C, G, cfg.proto_dim)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, 10, (C, G)), jnp.int32)
    gmask = jnp.asarray((rng.random((C, G)) < 0.9).astype(np.float32))

    out = sharded_eval_fn(mesh)(theta, qp, qids, tmask, gp, gids, gmask)
    ref = stacked_eval_program(theta, qp, qids, tmask, gp, gids, gmask)
    for k in out:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# (e) batched comm accounting
# ---------------------------------------------------------------------------


def test_commlog_many_equals_loop():
    a, b = CommLog(), CommLog()
    payload = {"x": np.zeros((7, 3), np.float32)}
    for _ in range(5):
        a.log_c2s(0, payload)
        a.log_s2c(1, 123)
    b.log_c2s_many(0, payload, 5)
    b.log_s2c_many(1, 123, 5)
    assert a.c2s == b.c2s and a.s2c == b.s2c
    assert a.total == b.total
