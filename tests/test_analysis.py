"""The static analyzer's own tests: each lint pass fires on a known-bad toy
program, the convention passes fire on a synthetic bad tree, and the real
repo is clean (zero non-baselined findings over every registered program).
"""
import json
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import conventions, lints, registry
from repro.analysis.lints import Finding
from repro.analysis.registry import ProgramSpec

_S = jax.ShapeDtypeStruct


def _spec(fn, args, name="toy", **kw):
    return ProgramSpec(name=name, fn=fn,
                       abstract_args=lambda: (args, {}),
                       module="tests.test_analysis", **kw)


def _codes(findings):
    return {f.code for f in findings}


# ---------------------------------------------------------------------------
# jaxpr passes on known-bad toy programs
# ---------------------------------------------------------------------------


def test_dtype_widen_fires_on_f64():
    def f(x):
        return jnp.sum(x.astype(jnp.float64))

    with jax.experimental.enable_x64():
        spec = _spec(f, (_S((8,), jnp.float32),))
        fs, _ = lints.run_jaxpr_lints(registry.trace(spec), spec)
    widen = [f_ for f_ in fs if f_.code == "dtype-widen"]
    assert widen and "float64" in widen[0].message


def test_dtype_widen_quiet_when_declared():
    def f(x):
        return jnp.sum(x.astype(jnp.float64))

    with jax.experimental.enable_x64():
        spec = _spec(f, (_S((8,), jnp.float32),),
                     allowed_dtypes=frozenset({"float32", "float64"}))
        fs, _ = lints.run_jaxpr_lints(registry.trace(spec), spec)
    assert "dtype-widen" not in _codes(fs)


def test_convert_churn_fires_on_roundtrip():
    def f(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32) + 1.0

    spec = _spec(f, (_S((16,), jnp.float32),))
    fs, _ = lints.run_jaxpr_lints(registry.trace(spec), spec)
    assert "convert-churn" in _codes(fs)


def test_host_callback_in_scan_body_fires():
    def body(c, x):
        y = jax.pure_callback(lambda v: np.asarray(v),
                              _S((), jnp.float32), x)
        return c + y, y

    def f(xs):
        out, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
        return out

    spec = _spec(f, (_S((4,), jnp.float32),))
    fs, _ = lints.run_jaxpr_lints(registry.trace(spec), spec)
    cb = [f_ for f_ in fs if f_.code == "host-callback"]
    assert cb and "INSIDE a loop body" in cb[0].message
    # and the escape hatch silences it
    spec_ok = _spec(f, (_S((4,), jnp.float32),), allow_callbacks=True)
    fs_ok, _ = lints.run_jaxpr_lints(registry.trace(spec_ok), spec_ok)
    assert "host-callback" not in _codes(fs_ok)


def test_undonated_carry_by_declaration():
    spec = _spec(lambda s, x: s + x,
                 (_S((8,), jnp.float32), _S((8,), jnp.float32)),
                 carry=(0,), donate=())
    fs = lints.lint_donation(spec)
    assert [f_.code for f_ in fs] == ["undonated-carry"]


def test_undonated_carry_by_trace():
    """Declared donate but the registered jit forgot donate_argnums."""
    args = (_S((8,), jnp.float32), _S((8,), jnp.float32))
    bad = _spec(jax.jit(lambda s, x: s + x), args, carry=(0,), donate=(0,))
    fs = lints.lint_donation(bad, registry.trace(bad))
    assert any("no donated invars" in f_.message for f_ in fs)
    good = _spec(jax.jit(lambda s, x: s + x, donate_argnums=(0,)), args,
                 carry=(0,), donate=(0,))
    assert not lints.lint_donation(good, registry.trace(good))


def test_dead_code_fires_on_unused_intermediate():
    def f(x):
        _ = jnp.dot(x, x.T)          # never reaches an output
        return jnp.sum(x)

    spec = _spec(f, (_S((32, 32), jnp.float32),))
    fs, _ = lints.run_jaxpr_lints(registry.trace(spec), spec)
    dead = [f_ for f_ in fs if f_.code == "dead-code"]
    assert dead and "dot_general" in dead[0].message


def test_peak_bytes_budget():
    def f(x):
        y = jnp.outer(x, x)          # (4096, 4096) f32 = 64 MB live
        return jnp.sum(y)

    spec = _spec(f, (_S((4096,), jnp.float32),), budget_bytes=1 << 20)
    closed = registry.trace(spec)
    fs, stats = lints.run_jaxpr_lints(closed, spec)
    assert "peak-bytes" in _codes(fs)
    assert stats["peak_bytes"] >= 64 * 1024 * 1024


# ---------------------------------------------------------------------------
# convention passes on a synthetic bad tree
# ---------------------------------------------------------------------------


@pytest.fixture
def bad_repo(tmp_path):
    k = tmp_path / "src" / "repro" / "kernels"
    k.mkdir(parents=True)
    (tmp_path / "src" / "repro" / "__init__.py").write_text("")
    (k / "__init__.py").write_text("")
    (k / "ref.py").write_text("def wired_ref(x):\n    return x\n")
    (k / "ops.py").write_text(textwrap.dedent("""\
        from repro.kernels import ref as REF
        from repro.kernels.wired import wired as _w

        def wired(x, *, backend=None):
            if backend == "ref":
                return REF.wired_ref(x)
            return _w(x)

        def orphan(x, *, backend=None):
            return x
    """))
    (k / "wired.py").write_text("def wired(x):\n    return x\n")
    (k / "lonely.py").write_text("def lonely(x):\n    return x\n")
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "test_wired.py").write_text(
        "import os\n\ndef test_wired():\n    assert True  # wired\n")
    return tmp_path


def test_kernel_conventions_fire(bad_repo):
    fs = conventions.lint_kernel_conventions(bad_repo)
    codes = _codes(fs)
    # orphan: no ref oracle, no parity test; lonely.py: not wired into ops
    assert "kernel-no-ref" in codes
    assert "kernel-no-parity-test" in codes
    assert any(f.code == "kernel-module-unwired" and "lonely" in f.message
               for f in fs)
    # the properly wired dispatcher is clean
    assert not any("`wired`" in f.message for f in fs)


def test_unused_imports_fire(bad_repo):
    fs = conventions.lint_unused_imports(bad_repo)
    assert any(f.code == "unused-import" and "os" in f.message for f in fs)


def test_fast_path_oracle_checks():
    no_oracle = _spec(lambda x: x, (_S((2,), jnp.float32),))
    broken = _spec(lambda x: x, (_S((2,), jnp.float32),),
                   oracle="repro.kernels.ref.does_not_exist")
    good = _spec(lambda x: x, (_S((2,), jnp.float32),),
                 oracle="repro.kernels.ref.pairwise_dist_ref")
    fs = conventions.lint_fast_path_oracles([no_oracle, broken, good])
    assert sorted(f.code for f in fs) == ["fast-path-no-oracle",
                                          "fast-path-oracle-unresolved"]


def test_dead_module_detection(bad_repo):
    (bad_repo / "src" / "repro" / "configs").mkdir()
    (bad_repo / "src" / "repro" / "configs" / "__init__.py").write_text("")
    (bad_repo / "src" / "repro" / "configs" / "orphaned.py").write_text(
        "X = 1\n")
    (bad_repo / "src" / "repro" / "configs" / "testonly.py").write_text(
        "Y = 2\n")
    (bad_repo / "tests" / "test_cfg.py").write_text(
        "from repro.configs import testonly\n")
    spec = ProgramSpec(name="kernels.wired", fn=lambda x: x,
                       abstract_args=lambda: ((), {}),
                       module="repro.kernels.ops")
    fs = conventions.lint_dead_modules(bad_repo, [spec])
    by_code = {f.code: f.message for f in fs}
    assert "orphaned" in by_code["dead-module"]
    assert "testonly" in by_code["seed-module"]


def test_dead_module_init_fanout_does_not_keep_alive(bad_repo):
    """A scope package init re-exporting a submodule (the registry
    pattern) must NOT count as registry reachability — only an import by
    name does. Tests importing the init still reach it (full graph), so
    the finding is seed-module, not dead-module."""
    cfg = bad_repo / "src" / "repro" / "configs"
    cfg.mkdir()
    (cfg / "__init__.py").write_text(
        "from repro.configs.fanout import X\n")
    (cfg / "fanout.py").write_text("X = 1\n")
    (bad_repo / "src" / "repro" / "uses_cfg.py").write_text(
        "import repro.configs\n")
    (bad_repo / "tests" / "test_cfg.py").write_text(
        "import repro.configs\n")
    spec = ProgramSpec(name="kernels.wired", fn=lambda x: x,
                       abstract_args=lambda: ((), {}),
                       module="repro.uses_cfg")
    fs = conventions.lint_dead_modules(bad_repo, [spec])
    assert any(f.code == "seed-module" and "fanout" in f.message
               for f in fs)


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------


def test_baseline_partition_and_stale():
    from repro.analysis.lint import partition_findings
    fs = [Finding("dead-code", "p1", "x is dead"),
          Finding("dtype-widen", "p2", "float64 crept in")]
    sups = [{"code": "dead-code", "program": "p1", "match": "dead",
             "reason": "known"},
            {"code": "host-callback", "program": "p9", "reason": "gone"}]
    new, base, stale = partition_findings(fs, sups)
    assert [f.code for f in new] == ["dtype-widen"]
    assert [f.code for f in base] == ["dead-code"]
    assert stale == [sups[1]]


# ---------------------------------------------------------------------------
# the real repo is clean
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_repo_programs_trace_and_lint_clean():
    """Every registered program traces; lints minus baseline == zero.

    This is the CI gate (scripts/run_tier1.sh) in test form: the acceptance
    floor is >= 8 traced programs covering the server round, stacked local
    train, the fused aggregate, batched eval, and the wire codec programs.
    """
    from repro.analysis.lint import (BASELINE_PATH, load_baseline,
                                     partition_findings, run)
    report = run()
    traced = [n for n, p in report["programs"].items() if p["traced"]]
    assert len(traced) >= 8, traced
    for needed in ("federated.fedstil_server_round",
                   "federated.stacked_local_train",
                   "kernels.fused_relevance_aggregate",
                   "federated.stacked_eval",
                   "kernels.batched_pairwise_dist",
                   "kernels.batched_quantize",
                   "comm.batched_encode",
                   "comm.batched_decode"):
        assert needed in traced
    new, base, stale = partition_findings(
        report["findings"], load_baseline(BASELINE_PATH))
    assert not new, [f.as_dict() for f in new]
    assert not stale, stale
    # every baseline entry carries its why
    for s in json.loads(BASELINE_PATH.read_text())["suppressions"]:
        assert s.get("reason"), s


def test_registered_programs_declare_resolvable_oracles():
    specs = registry.iter_programs()
    fs = conventions.lint_fast_path_oracles(specs)
    assert not fs, [f.as_dict() for f in fs]
