"""repro.obs: histogram/percentile math, tracer semantics, reporter.

The histogram tests pin the subsystem's accuracy contract: a reported
percentile is the upper edge of the bucket holding the true sample
percentile, so it must bound ``np.percentile`` from above within one
bucket's relative width. The tracer tests pin the off-by-default-cheap
contract (null path records nothing and never syncs) and the JSONL
round trip the report CLI consumes.
"""
import json
import math

import numpy as np
import pytest

from repro.obs import metrics as M
from repro.obs import trace as T
from repro.obs.report import summarize, telemetry_block


# ---------------------------------------------------------------------------
# LatencyHistogram vs numpy percentile
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [50, 90, 99])
@pytest.mark.parametrize("dist", ["uniform", "lognormal", "bimodal"])
def test_histogram_percentile_bounds_numpy(dist, q):
    rng = np.random.default_rng(hash((dist, q)) % (2**32))
    if dist == "uniform":
        xs = rng.uniform(1e-4, 0.5, 5000)
    elif dist == "lognormal":
        xs = np.exp(rng.normal(math.log(5e-3), 1.0, 5000))
    else:
        xs = np.concatenate([rng.uniform(1e-4, 3e-4, 2500),
                             rng.uniform(0.1, 0.2, 2500)])
    xs = np.clip(xs, 1.1e-5, 9.0)          # stay inside the bucket span
    h = M.LatencyHistogram()
    h.record_many(xs)
    got = h.percentile(q)
    true = float(np.percentile(xs, q, method="inverted_cdf"))
    # upper bound, tight to one log bucket's width
    bucket_ratio = (10.0 / 1e-5) ** (1.0 / 64)
    assert got >= true * (1 - 1e-12)
    assert got <= true * bucket_ratio * (1 + 1e-9)


def test_histogram_empty_and_single_sample():
    h = M.LatencyHistogram()
    assert math.isnan(h.percentile(50))
    assert math.isnan(h.mean)
    h.record(0.003)
    # one sample: every percentile is that sample's bucket edge
    assert h.percentile(1) == h.percentile(50) == h.percentile(99)
    assert h.percentile(50) >= 0.003
    assert h.mean == pytest.approx(0.003)
    assert h.snapshot()["n"] == 1


def test_histogram_out_of_range_clamps():
    h = M.LatencyHistogram()
    h.record(1e-9)                 # below lo -> first bucket
    h.record(100.0)                # above hi -> overflow bucket
    assert h.n == 2
    assert int(h.counts[0]) == 1 and int(h.counts[-1]) == 1
    assert h.percentile(99) == h.edges[-1]


def test_histogram_merge_matches_combined_stream():
    rng = np.random.default_rng(0)
    a, b = rng.uniform(1e-4, 1.0, 400), rng.uniform(1e-3, 0.1, 600)
    ha, hb, hc = (M.LatencyHistogram() for _ in range(3))
    ha.record_many(a)
    hb.record_many(b)
    hc.record_many(np.concatenate([a, b]))
    ha.merge(hb)
    np.testing.assert_array_equal(ha.counts, hc.counts)
    assert ha.n == hc.n == 1000
    for q in (50, 90, 99):
        assert ha.percentile(q) == hc.percentile(q)


def test_rolling_meter_window():
    m = M.RollingMeter(window_s=1.0)
    m.tick(10, now=100.0)
    m.tick(5, now=100.5)
    assert m.rate(now=100.6) == pytest.approx(15.0)
    assert m.rate(now=101.2) == pytest.approx(5.0)   # first burst evicted
    assert m.rate(now=105.0) == 0.0
    assert m.total == 15


def test_serve_stats_snapshot_shapes():
    class _T:
        latency, queue_s, service_s = 0.004, 0.001, 0.003
    s = M.ServeStats()
    s.record_launch(7, deficit=[3, 0, 1])
    for _ in range(4):
        s.record_ticket(_T())
    snap = s.snapshot()
    assert snap["completed"] == 4 and snap["launches"] == 1
    assert snap["queue_depth"] == {"mean": 7.0, "max": 7}
    assert snap["latency"]["n"] == 4
    assert snap["drr_deficit_spread"] == 3.0
    json.dumps(snap)               # JSON-ready by contract


# ---------------------------------------------------------------------------
# tracer: null path, activation, JSONL round trip
# ---------------------------------------------------------------------------


def test_null_tracer_records_nothing_and_sync_is_identity():
    assert not T.is_active()
    sentinel = object()
    with T.span("x", cat="phase") as sp:
        assert sp.sync(sentinel) is sentinel     # no block_until_ready
    T.metric("x", {"a": 1.0})                    # no-op, must not raise


def test_active_tracer_restores_previous_on_exit():
    tr = T.Tracer()
    with T.active(tr):
        assert T.is_active() and T.get_tracer() is tr
        with T.suspended():
            assert not T.is_active()
        assert T.get_tracer() is tr
    assert not T.is_active()


def test_span_and_metric_events_jsonl_round_trip(tmp_path):
    path = tmp_path / "run.jsonl"
    tr = T.Tracer(path=path)
    with T.active(tr):
        with T.span("round.server", cat="phase", round=3) as sp:
            sp.sync(np.zeros(2))
        T.metric("server.relevance",
                 {"staleness": np.array([0.0, 2.0]), "scalar": np.float32(1)},
                 round=3)
    tr.close()
    events = T.RunLog.read(path)
    kinds = [e["kind"] for e in events]
    assert kinds.count("span") == 1 and kinds.count("metric") == 1
    span = next(e for e in events if e["kind"] == "span")
    assert span["name"] == "round.server" and span["round"] == 3
    assert span["dur"] >= 0.0
    met = next(e for e in events if e["kind"] == "metric")
    assert met["values"]["staleness"] == [0.0, 2.0]    # device -> list
    assert met["values"]["scalar"] == 1.0


def test_chrome_trace_export():
    tr = T.Tracer()
    with T.active(tr):
        with T.span("a", cat="stage"):
            pass
        T.metric("m", {"v": 1.0})
    ct = T.chrome_trace(tr.events)
    phs = [e["ph"] for e in ct["traceEvents"]]
    assert "X" in phs and "i" in phs
    x = next(e for e in ct["traceEvents"] if e["ph"] == "X")
    assert x["tid"] == "stage" and x["dur"] >= 0.0


# ---------------------------------------------------------------------------
# report aggregation + device metric helpers
# ---------------------------------------------------------------------------


def test_summarize_and_telemetry_block():
    tr = T.Tracer()
    with T.active(tr):
        for name, dur in (("round.local_train", None), ("round.server", None)):
            with T.span(name, cat="phase"):
                pass
        with T.span("server.relevance", cat="stage"):
            pass
        T.metric("server.relevance", {"staleness": [0.0, 1.0]}, round=0)
        T.metric("server.relevance", {"staleness": [1.0, 0.0]}, round=1)
    s = summarize(tr.events)
    assert set(s["phases"]) == {"round.local_train", "round.server"}
    assert abs(sum(g["share"] for g in s["phases"].values()) - 1.0) < 1e-9
    assert s["clients"]["staleness"] == [1.0, 0.0]     # LAST round wins
    assert s["clients"]["round"] == 1
    block = telemetry_block(tr.events)
    assert block["events"]["spans"] == 3
    assert "serve" not in block                        # no serve metrics
    json.dumps(block)


def test_update_staleness_partial_mask():
    jnp = pytest.importorskip("jax.numpy")
    stale = jnp.asarray([0.0, 3.0, 1.0])
    mask = jnp.asarray([1.0, 0.0, 1.0])
    out = np.asarray(M.update_staleness(stale, mask))
    np.testing.assert_array_equal(out, [0.0, 4.0, 0.0])


def test_relevance_metrics_values():
    jnp = pytest.importorskip("jax.numpy")
    W = jnp.asarray([[0.0, 1.0], [0.5, 0.5]])
    valid = jnp.asarray([[1.0, 0.0], [1.0, 1.0]])
    stale = jnp.asarray([2.0, 0.0])
    m = {k: np.asarray(v) for k, v in
         M.relevance_metrics(W, valid, stale).items()}
    np.testing.assert_allclose(m["row_mass"], [1.0, 1.0])
    np.testing.assert_allclose(m["row_density"], [0.5, 1.0])
    np.testing.assert_allclose(m["self_weight"], [0.0, 0.5])
    np.testing.assert_allclose(m["hist_fill"], [1.0, 2.0])
    np.testing.assert_allclose(m["staleness"], [2.0, 0.0])
