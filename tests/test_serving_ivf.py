"""IVF shortlist serving guarantees (repro.serving phase 2):

  * kernel parity: ``batched_cluster_assign`` and ``batched_ivf_shortlist``
    dispatchers, Pallas interpret path vs the jnp ref — including empty
    bucket slots, whole empty buckets, and all-invalid clients;
  * build correctness: the jitted IVF refresh places every valid row in
    exactly one bucket slot (so recall@k == 1.0 at nprobe == nlist is
    structural), matches its numpy host oracle, and an incremental
    ``update`` rebuilds the image bit-identically to from-scratch;
  * query fidelity: full-probe ivf == exact int8 path; clustered-data
    recall at small nprobe; batch-composition invariance in ivf mode;
  * batcher: deficit-round-robin fairness under a scarce step budget vs
    fifo starvation, queueing/service latency split, the open-loop
    pacer's scheduled-arrival stamps, and ``Ticket.latency`` raising a
    clear error before completion;
  * sharding: ``serving_index_specs`` covers the resident image with
    leading-client-dim ("data" axis) specs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import edge_model as EM
from repro.kernels import ops
from repro.kernels import ref as REF
from repro.serving import (ContinuousBatcher, GalleryIndex, RetrievalEngine,
                           recall_at_k, run_open_loop)
from repro.serving.index import ivf_refresh_host
from repro.sharding import specs as SP

CFG = EM.EdgeModelConfig()


def _l2n(x):
    return x / np.sqrt(np.maximum((x * x).sum(-1, keepdims=True), 1e-12))


def _stack_thetas(C, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), C)
    thetas = [EM.init_adaptive_layers(k, CFG) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *thetas)


def _clustered_protos(rng, n, *, rank=8, rho=0.25, n_per=8):
    """Rows clustered around unit id-centers in a low-rank subspace (the
    structure that makes an IVF shortlist meaningful; see the serve
    bench). Returns (rows, centers)."""
    U, _ = np.linalg.qr(rng.standard_normal((CFG.proto_dim, rank)))
    centers = _l2n(_l2n(rng.standard_normal((n // n_per, rank))
                        ).astype(np.float32) @ U.T.astype(np.float32))
    idx = np.repeat(np.arange(n // n_per), n_per)
    noise = _l2n(rng.standard_normal((n, CFG.proto_dim))).astype(np.float32)
    return (_l2n(centers[idx] + rho * noise).astype(np.float32),
            centers.astype(np.float32))


def _mk_ivf_index(C=3, G=256, seed=0, **kw):
    rng = np.random.default_rng(seed)
    protos, centers = [], []
    for _ in range(C):
        p, ctr = _clustered_protos(rng, G)
        protos.append(p)
        centers.append(ctr)
    ids = [np.arange(G, dtype=np.int32) for _ in range(C)]
    kw.setdefault("nlist", 16)
    kw.setdefault("bcap", 32)
    kw.setdefault("ivf_iters", 4)
    return GalleryIndex(protos, ids, **kw), centers, rng


@pytest.fixture(scope="module")
def ivf_engines():
    index, centers, rng = _mk_ivf_index()
    theta = _stack_thetas(index.n_clients)
    eng8 = RetrievalEngine(index, theta, k=10, mode="int8")
    engv = RetrievalEngine(index, theta, k=10, mode="ivf", nprobe=4,
                           refresh=False)
    return index, theta, eng8, engv, centers, rng


def _queries(rng, centers, B, rho=0.25):
    C = len(centers)
    qp = np.stack([
        _l2n(c[rng.integers(0, len(c), B)]
             + rho * _l2n(rng.standard_normal((B, CFG.proto_dim))))
        for c in centers]).astype(np.float32)
    return qp, np.ones((C, B), np.float32)


@pytest.mark.parametrize("C,B,F,L", [(2, 5, 32, 7), (1, 1, 64, 3)])
def test_batched_cluster_assign_parity(C, B, F, L):
    """Dispatcher ref vs Pallas interpret: identical probe ids."""
    rng = np.random.default_rng(1)
    qf = jnp.asarray(rng.standard_normal((C, B, F)).astype(np.float32))
    cent = jnp.asarray(rng.standard_normal((C, L, F)).astype(np.float32))
    cn2 = jnp.sum(cent * cent, -1)
    p_ref = ops.batched_cluster_assign(qf, cent, cn2, nprobe=3,
                                       backend="ref")
    p_int = ops.batched_cluster_assign(qf, cent, cn2, nprobe=3,
                                       backend="interpret")
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_int))
    assert p_ref.shape == (C, B, 3) and p_ref.dtype == jnp.int32
    # nearest-first vs the ref distance matrix
    d = np.asarray(REF.batched_cluster_assign_ref(qf, cent, cn2, nprobe=L))
    np.testing.assert_array_equal(np.asarray(p_ref), d[..., :3])


def test_batched_ivf_shortlist_parity_empty_buckets():
    """Ref vs interpret over an image with empty slots, a whole empty
    bucket, and an all-empty client — dists allclose, ids exact."""
    rng = np.random.default_rng(2)
    C, B, F, L, K, P = 3, 4, 32, 6, 5, 3
    qf = jnp.asarray(rng.standard_normal((C, B, F)).astype(np.float32))
    bids = rng.integers(0, 999, (C, L, K)).astype(np.int32)
    bids[0, 2, 3:] = -1                      # partial bucket
    bids[1, 4] = -1                          # whole empty bucket
    bids[2] = -1                             # all-empty client
    bq = rng.integers(-127, 128, (C, L, K, F)).astype(np.int8)
    bq = np.where(bids[..., None] >= 0, bq, 0)
    scale = (0.001 + rng.random((C, L, K))).astype(np.float32)
    scale = np.where(bids >= 0, scale, 1.0)
    n2 = np.where(bids >= 0, rng.random((C, L, K)), 0.0).astype(np.float32)
    pack = jnp.asarray(np.stack([scale, n2, bids.view(np.float32)], axis=2))
    probe = jnp.asarray(rng.integers(0, L, (C, B, P)).astype(np.int32))
    d_ref, i_ref = ops.batched_ivf_shortlist(qf, probe, jnp.asarray(bq),
                                             pack, backend="ref")
    d_int, i_int = ops.batched_ivf_shortlist(qf, probe, jnp.asarray(bq),
                                             pack, backend="interpret")
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_int),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_int))
    # ids come back from the packed bitcast lane, empty slots as -1
    i_man = np.stack([bids[c][np.asarray(probe)[c]].reshape(B, P * K)
                      for c in range(C)])
    np.testing.assert_array_equal(np.asarray(i_ref), i_man)


def test_ivf_build_places_every_valid_row(ivf_engines):
    index, _, _, _, _, _ = ivf_engines
    binv = np.asarray(index.binv)
    G = index.capacity
    for c in range(index.n_clients):
        placed = binv[c][binv[c] >= 0]
        assert len(placed) == G
        assert len(np.unique(placed)) == G
    # bucket ids in the packed sidecar mirror gids[binv]
    pack = np.asarray(index.pack)
    bids = pack[:, :, 2, :].view(np.int32)
    gids = np.asarray(index.gids)
    safe = np.maximum(binv, 0)
    expect = np.where(binv >= 0,
                      np.take_along_axis(
                          gids, safe.reshape(index.n_clients, -1),
                          axis=1).reshape(binv.shape), -1)
    np.testing.assert_array_equal(bids, expect)


def test_ivf_full_probe_matches_exact(ivf_engines):
    """nprobe == nlist covers every bucket -> the shortlist IS the whole
    gallery: recall@k == 1.0 and distances match the exact int8 path."""
    index, theta, eng8, _, centers, rng = ivf_engines
    engall = RetrievalEngine(index, theta, k=10, mode="ivf",
                             nprobe=index.nlist, refresh=False)
    qp, qm = _queries(rng, centers, 8)
    qm[0, 6:] = 0.0                          # padded slots -> -1
    i8, d8 = eng8.query_batch(qp, qm)
    iv, dv = engall.query_batch(qp, qm)
    assert recall_at_k(iv, i8, qm) == 1.0
    np.testing.assert_allclose(dv[qm > 0], d8[qm > 0], atol=1e-4)
    assert np.all(iv[0, 6:] == -1)


def test_ivf_recall_clustered(ivf_engines):
    """At nprobe = nlist/4 on clustered data the shortlist keeps nearly
    all true neighbors (the bench measures this at G=131k)."""
    _, _, eng8, engv, centers, rng = ivf_engines
    qp, qm = _queries(rng, centers, 32)
    i8, _ = eng8.query_batch(qp, qm)
    iv, _ = engv.query_batch(qp, qm)
    assert recall_at_k(iv, i8, qm) >= 0.9


def test_ivf_query_matches_host_oracle(ivf_engines):
    index, theta, _, engv, centers, rng = ivf_engines
    qp, qm = _queries(rng, centers, 6)
    from repro.serving import query_ivf_host
    ids_d, dist_d = engv.query_batch(qp, qm)
    ids_h, dist_h = query_ivf_host(
        engv.theta, index.bn_mu, index.bn_sd, qp, qm, index.cent,
        index.cn2, index.bq, index.pack, k=10, nprobe=engv.nprobe)
    np.testing.assert_array_equal(ids_d, ids_h)
    np.testing.assert_allclose(dist_d, dist_h, atol=1e-4)


def test_ivf_all_invalid_client():
    """A client with zero valid rows builds an empty image (all buckets
    empty) and answers every query with -1, like the exact path."""
    rng = np.random.default_rng(3)
    p0, _ = _clustered_protos(rng, 64)
    index = GalleryIndex([p0, np.zeros((0, CFG.proto_dim), np.float32)],
                         [np.arange(64, dtype=np.int32),
                          np.zeros((0,), np.int32)],
                         nlist=8, bcap=16, ivf_iters=2)
    theta = _stack_thetas(2, seed=3)
    eng8 = RetrievalEngine(index, theta, k=5, mode="int8")
    engv = RetrievalEngine(index, theta, k=5, mode="ivf", nprobe=2,
                           refresh=False)
    assert np.all(np.asarray(index.binv)[1] == -1)
    qp = rng.standard_normal((2, 3, CFG.proto_dim)).astype(np.float32)
    qm = np.ones((2, 3), np.float32)
    i8, _ = eng8.query_batch(qp, qm)
    iv, _ = engv.query_batch(qp, qm)
    assert np.all(i8[1] == -1) and np.all(iv[1] == -1)


def test_ivf_refresh_matches_host_oracle(ivf_engines):
    """Jitted build vs the numpy replica: flat image bit-exact, centroids
    allclose (fp reduction order differs), placement invariants on both."""
    index, theta, _, _, _, _ = ivf_engines
    gmask = (index.gids_host >= 0).astype(np.float32)
    out = ivf_refresh_host(theta, index.gp, gmask, index.gids_host,
                           nlist=index.nlist, bcap=index.bcap,
                           iters=index.ivf_iters,
                           train_cap=index.ivf_train_cap,
                           balance=index.ivf_balance)
    hq, hs, hn2, hmu, hsd, hf, hcent, hcn2, hbq, hpack, hbinv = out
    np.testing.assert_array_equal(hq, np.asarray(index.gq))
    np.testing.assert_allclose(hcent, np.asarray(index.cent),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(hcn2, np.asarray(index.cn2), atol=5e-3)
    G = index.capacity
    for c in range(index.n_clients):
        placed = hbinv[c][hbinv[c] >= 0]
        assert len(placed) == G and len(np.unique(placed)) == G


def test_ivf_incremental_refresh_identical(ivf_engines):
    """update(theta2) == a from-scratch engine, bit for bit, across the
    whole IVF image (deterministic jitted build)."""
    index, theta, _, _, _, rng = ivf_engines
    eng = RetrievalEngine(_mk_ivf_index()[0], theta, k=5, mode="ivf",
                          nprobe=4)
    theta2 = _stack_thetas(index.n_clients, seed=7)
    eng.update(theta2)
    fresh = RetrievalEngine(_mk_ivf_index()[0], theta2, k=5, mode="ivf",
                            nprobe=4)
    for name in ("cent", "cn2", "bq", "pack", "binv"):
        np.testing.assert_array_equal(
            np.asarray(getattr(eng.index, name)),
            np.asarray(getattr(fresh.index, name)), err_msg=name)
    qp = rng.standard_normal((index.n_clients, 3,
                              CFG.proto_dim)).astype(np.float32)
    qm = np.ones((index.n_clients, 3), np.float32)
    np.testing.assert_array_equal(eng.query_batch(qp, qm)[0],
                                  fresh.query_batch(qp, qm)[0])


def test_ivf_batch_composition_invariance(ivf_engines):
    """Frozen BN + per-query probe selection: an ivf answer is identical
    no matter which batch the query rides in."""
    _, _, _, engv, _, rng = ivf_engines
    C = engv.index.n_clients
    probe = rng.standard_normal(CFG.proto_dim).astype(np.float32)
    qp1 = np.zeros((C, 1, CFG.proto_dim), np.float32)
    qp1[1, 0] = probe
    m1 = np.zeros((C, 1), np.float32)
    m1[1, 0] = 1.0
    ids1, d1 = engv.query_batch(qp1, m1)
    qp8 = rng.standard_normal((C, 8, CFG.proto_dim)).astype(np.float32)
    qp8[1, 3] = probe
    m8 = np.ones((C, 8), np.float32)
    ids8, d8 = engv.query_batch(qp8, m8)
    np.testing.assert_array_equal(ids1[1, 0], ids8[1, 3])
    np.testing.assert_allclose(d1[1, 0], d8[1, 3], atol=1e-5)


# ---------------------------------------------------------------------------
# batcher satellites: admission fairness, latency split, pacer
# ---------------------------------------------------------------------------


def _flood(batcher, rng, counts):
    for c, n in enumerate(counts):
        for i in range(n):
            batcher.submit(c, rng.standard_normal(CFG.proto_dim), qid=i)


def test_fifo_starves_under_budget(ivf_engines):
    _, _, eng8, _, _, rng = ivf_engines
    b = ContinuousBatcher(eng8, batch=4, policy="fifo", step_budget=4)
    _flood(b, rng, [12, 4, 4])
    first = b.step()
    assert {t.client for t in first} == {0}


def test_drr_shares_budget(ivf_engines):
    """Every backlogged client is served every step under drr; with the
    same budget fifo gives all slots to client 0 (test above)."""
    _, _, eng8, _, _, rng = ivf_engines
    b = ContinuousBatcher(eng8, batch=4, policy="drr", step_budget=4)
    assert b.quantum == 1
    _flood(b, rng, [12, 4, 4])
    steps = []
    while b.pending:
        steps.append(b.step())
    served = [{t.client for t in s} for s in steps]
    # while all three are backlogged, all three are served each step
    assert served[0] == {0, 1, 2} and served[1] == {0, 1, 2}
    # short queues finish no later than the hot client
    last = {c: max(i for i, s in enumerate(steps)
                   if any(t.client == c for t in s)) for c in range(3)}
    assert last[1] < last[0] and last[2] < last[0]
    # every ticket still answered exactly once
    assert sum(len(s) for s in steps) == 20


def test_ticket_latency_split(ivf_engines):
    _, _, eng8, _, _, rng = ivf_engines
    b = ContinuousBatcher(eng8, batch=4)
    t = b.submit(0, rng.standard_normal(CFG.proto_dim))
    with pytest.raises(RuntimeError, match="not completed"):
        _ = t.latency
    with pytest.raises(RuntimeError, match="not been launched"):
        _ = t.queue_s
    b.step()
    assert t.t_submit <= t.t_launch <= t.t_done
    assert t.latency == pytest.approx(t.queue_s + t.service_s)


def test_open_loop_scheduled_arrivals(ivf_engines):
    """The pacer stamps tickets with their scheduled arrival times (exact
    uniform spacing) and keeps up with a rate the engine can sustain."""
    _, _, eng8, _, _, rng = ivf_engines
    b = ContinuousBatcher(eng8, batch=4)
    stream = [(i % 3, rng.standard_normal(CFG.proto_dim), i)
              for i in range(12)]
    res = run_open_loop(b, stream, rate_qps=100.0)
    assert res["n"] == 12
    ts = sorted(t.t_submit for t in res["tickets"])
    np.testing.assert_allclose(np.diff(ts), 0.01, rtol=1e-6)
    for key in ("queue_p50_ms", "service_p50_ms", "p99_ms"):
        assert key in res
    # scheduled for 12 arrivals at 100 qps = 0.11 s; generous slack for CI
    assert res["wall_s"] < 2.0


def test_serving_index_specs(ivf_engines):
    """Every resident array is covered with a leading-"data" row spec of
    the right rank, and the specs place on a mesh."""
    index, _, _, _, _, _ = ivf_engines
    specs = SP.serving_index_specs()
    arrays = {"gq": index.gq, "gscale": index.gscale, "gn2": index.gn2,
              "gids": index.gids, "gf": index.gf, "bn_mu": index.bn_mu,
              "bn_sd": index.bn_sd, "cent": index.cent, "cn2": index.cn2,
              "bq": index.bq, "pack": index.pack, "binv": index.binv}
    mesh = SP.engine_mesh(jax.devices()[:1])
    for name, arr in arrays.items():
        spec = specs[name]
        assert len(spec) == arr.ndim, name
        assert spec[0] == "data", name
        jax.device_put(jnp.asarray(arr),
                       jax.sharding.NamedSharding(mesh, spec))
