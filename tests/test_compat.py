"""JAX version-compat shim tests (run on whatever JAX is installed)."""
import types

import jax
import numpy as np
import pytest

from repro.common import compat


def test_resolve_shard_map_new_layout():
    sentinel = object()
    fake_jax = types.SimpleNamespace(shard_map=sentinel)
    assert compat.resolve_shard_map(fake_jax) is sentinel


def test_resolve_shard_map_old_layout():
    fake_jax = types.SimpleNamespace()           # no public shard_map
    fn = compat.resolve_shard_map(fake_jax)
    assert callable(fn)


def test_adapt_check_kwarg_layouts():
    new = frozenset({"f", "mesh", "in_specs", "out_specs", "check_vma"})
    old = frozenset({"f", "mesh", "in_specs", "out_specs", "check_rep"})
    assert compat.adapt_check_kwarg(new, None) == {}
    assert compat.adapt_check_kwarg(new, True) == {"check_vma": True}
    assert compat.adapt_check_kwarg(new, False) == {"check_vma": False}
    # 0.4.x checker rejects valid grad programs: always off there
    for v in (None, True, False):
        assert compat.adapt_check_kwarg(old, v) == {"check_rep": False}
    assert compat.adapt_check_kwarg(frozenset({"f"}), True) == {}


def test_shard_map_executes_on_installed_jax():
    """The shimmed shard_map + set_mesh run a real collective program."""
    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("x",))
    P = jax.sharding.PartitionSpec

    def f(a):
        return jax.lax.psum(a, "x")

    fn = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P("x"),
                                  out_specs=P(), check_vma=True))
    with compat.set_mesh(mesh):
        out = fn(np.arange(4.0, dtype=np.float32))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


def test_set_mesh_is_context_manager():
    mesh = jax.make_mesh((1,), ("x",))
    with compat.set_mesh(mesh):
        pass                                     # usable as a context


def test_axis_size_and_pcast_inside_shard_map():
    mesh = jax.make_mesh((1,), ("x",))
    P = jax.sharding.PartitionSpec

    def f(a):
        s = compat.axis_size("x")
        return compat.pcast_varying(a, ("x",)) * s

    fn = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P("x"),
                                  out_specs=P("x")))
    out = fn(np.ones(2, np.float32))
    np.testing.assert_allclose(np.asarray(out), np.ones(2))


def test_default_interpret_matches_backend():
    assert compat.default_interpret() == (jax.default_backend() != "tpu")
