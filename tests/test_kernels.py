"""Per-kernel allclose tests: Pallas (interpret=True on CPU) vs the pure-jnp
oracle in repro.kernels.ref, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as REF
from repro.kernels.adaptive_combine import adaptive_combine
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kl_similarity import kl_similarity
from repro.kernels.pairwise_dist import batched_pairwise_dist, pairwise_dist
from repro.kernels.relevance_aggregate import relevance_aggregate


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Sq,Sk,hd", [
    (1, 2, 128, 128, 64),
    (2, 1, 256, 256, 64),
    (1, 2, 128, 256, 128),   # cross-ish (non-square, non-causal only)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, H, Sq, Sk, hd, dtype, causal):
    if causal and Sq != Sk:
        pytest.skip("causal requires square here")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k1, (B, H, Sq, hd), dtype)
    k = _rand(k2, (B, H, Sk, hd), dtype)
    v = _rand(k3, (B, H, Sk, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, q_block=64, kv_block=64,
                          interpret=True)
    ref = REF.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Q,G,D", [(64, 64, 32), (130, 70, 128), (8, 300, 64)])
def test_pairwise_dist(Q, G, D, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    q = _rand(k1, (Q, D), dtype)
    g = _rand(k2, (G, D), dtype)
    out = pairwise_dist(q, g, q_block=64, g_block=64, interpret=True)
    ref = REF.pairwise_dist_ref(q, g)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,Q,G,D", [(1, 64, 64, 32), (3, 30, 130, 64),
                                     (5, 8, 300, 16)])
def test_batched_pairwise_dist(C, Q, G, D, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    q = _rand(k1, (C, Q, D), dtype)
    g = _rand(k2, (C, G, D), dtype)
    out = batched_pairwise_dist(q, g, q_block=32, g_block=64, interpret=True)
    ref = REF.batched_pairwise_dist_ref(q, g)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol, rtol=tol)
    # and per-client equivalence with the unbatched kernel path
    per = jnp.stack([pairwise_dist(q[c], g[c], interpret=True)
                     for c in range(C)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(per),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64,), (33, 17), (8, 128, 9), (100000,)])
def test_adaptive_combine(shape, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    b = _rand(k1, shape, dtype)
    al = _rand(k2, shape, dtype)
    a = _rand(k3, shape, dtype)
    out = adaptive_combine(b, al, a, interpret=True)
    ref = REF.adaptive_combine_ref(b, al, a)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,P", [(5, 1000), (8, 4096), (3, 257)])
def test_relevance_aggregate(C, P, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    w = jax.nn.softmax(jax.random.normal(k1, (C, C)), -1)
    th = _rand(k2, (C, P), dtype)
    out = relevance_aggregate(w, th, p_block=512, interpret=True)
    ref = REF.relevance_aggregate_ref(w, th)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("N,M,D", [(16, 16, 64), (40, 70, 128), (5, 5, 32)])
def test_kl_similarity(N, M, D):
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    a = jax.random.normal(k1, (N, D))
    b = jax.random.normal(k2, (M, D))
    out = kl_similarity(a, b, n_block=16, m_block=16, interpret=True)
    ref = REF.kl_similarity_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)
    # similarity of a row with itself is exactly 1
    self_sim = kl_similarity(a, a, interpret=True)
    np.testing.assert_allclose(np.diag(np.asarray(self_sim)), 1.0, atol=1e-5)


@pytest.mark.parametrize("C,P,chunk", [(3, 1000, 128), (5, 4096, 256),
                                       (1, 100, 64), (4, 257, 256)])
def test_batched_quantize(C, P, chunk):
    """Wire-codec int8 kernel vs oracle: identical codes and scales, and
    dequantized error within half a quantization step per chunk."""
    from repro.kernels.quantize import batched_dequantize, batched_quantize
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (C, P), jnp.float32) * 3.0
    q, s = batched_quantize(x, chunk=chunk, interpret=True)
    qr, sr = REF.batched_quantize_ref(x, chunk=chunk)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    # interpret-mode division can differ from the jnp oracle by 1 ULP
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    d = batched_dequantize(q, s, chunk=chunk, interpret=True)
    dr = REF.batched_dequantize_ref(qr, sr, chunk=chunk)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr),
                               rtol=1e-6, atol=1e-7)
    err = np.abs(np.asarray(d) - np.asarray(x))
    assert err.max() <= float(jnp.abs(x).max()) / 127.0 * 0.5 + 1e-7


@pytest.mark.parametrize("C,P,group,kg", [(3, 1000, 8, 3), (2, 4096, 8, 1),
                                          (4, 257, 8, 4), (2, 640, 16, 5)])
def test_batched_topk_pack_kernel(C, P, group, kg):
    """Grouped top-k pack/unpack kernels vs oracles: bit-identical values,
    indices, and dense reconstructions; per-group top-kg invariant."""
    from repro.kernels.topk_pack import batched_topk_pack, batched_topk_unpack
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (C, P), jnp.float32)
    v, i = batched_topk_pack(x, group=group, kg=kg, interpret=True)
    vr, ir = REF.batched_topk_pack_ref(x, group=group, kg=kg)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    u = batched_topk_unpack(v, i, p=P, group=group, kg=kg, interpret=True)
    ur = REF.batched_topk_unpack_ref(vr, ir, p=P, group=group, kg=kg)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(ur))
    # per-group invariant: kept entries are each group's kg largest
    xa = np.abs(np.asarray(x))
    un = np.asarray(u)
    for c in range(C):
        for b in range(0, P - group + 1, group):
            grp, kept = xa[c, b:b + group], un[c, b:b + group] != 0
            if kept.sum() == kg:
                assert grp[kept].min() >= np.sort(grp)[-kg] - 1e-7


@pytest.mark.parametrize("C,P,group,kg", [(3, 1000, 8, 3), (2, 4096, 8, 1),
                                          (4, 257, 8, 4), (2, 640, 16, 5)])
def test_batched_idx_bitpack_kernel(C, P, group, kg):
    """Index bit-pack/unpack kernels vs oracles: bit-identical packed
    planes, exact index round-trip, and the 10.7x (at group=8) byte
    shrink vs int32."""
    from repro.kernels.topk_pack import (batched_idx_bitpack,
                                         batched_idx_bitunpack,
                                         batched_topk_pack)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (C, P), jnp.float32)
    _, idx = batched_topk_pack(x, group=group, kg=kg, interpret=True)
    packed = batched_idx_bitpack(idx, group=group, kg=kg, interpret=True)
    packed_r = REF.batched_idx_bitpack_ref(idx, group=group, kg=kg)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(packed_r))
    K = idx.shape[1]
    bits = (group - 1).bit_length()
    assert packed.dtype == jnp.uint8
    assert packed.shape == (C, bits * ((K + 7) // 8))
    back = batched_idx_bitunpack(packed, k=K, group=group, kg=kg,
                                 interpret=True)
    back_r = REF.batched_idx_bitunpack_ref(packed_r, k=K, group=group, kg=kg)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(back_r), np.asarray(idx))
