"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model<=512, <=4 experts) runs one forward/train
step on CPU — output shapes asserted, no NaNs. Plus a decode-vs-forward
consistency check (the KV-cache/state path must predict the same tokens as
the teacher-forced forward pass)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, forward, init_cache, init_params, loss_fn
from repro.models import layers as L
from repro.train import init_train_state, make_train_step


def _batch(cfg, key, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    x, aux = forward(cfg, params, batch)
    B = batch["tokens"].shape[0]
    S_total = batch["tokens"].shape[1] + (
        cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert x.shape == (B, S_total, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(x)))
    loss, (ce, moe_aux) = loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    st = init_train_state(cfg, key)
    step = jax.jit(make_train_step(cfg, tie_lambda=1e-4))
    batch = _batch(cfg, key)
    tr, opt, metrics = step(st.frozen, st.B, st.trainable, st.opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # a second step must further change trainables & keep finiteness
    tr2, opt2, m2 = step(st.frozen, st.B, tr, opt, batch)
    assert np.isfinite(float(m2["loss"]))
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), tr, tr2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Greedy next-token from the cache path == teacher-forced forward."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 2, 8
    batch = _batch(cfg, key, B=B, S=S)
    toks = batch["tokens"]

    # teacher-forced: argmax over each position's logits
    x, _ = forward(cfg, params, batch)
    if cfg.family == "vlm":
        x = x[:, cfg.n_vision_tokens:]
    from repro.common.axes import UNSHARDED
    fwd_next, _ = L.lm_head_logits(cfg, params["head"], x, UNSHARDED)

    # decode path: feed tokens one by one
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts from vision-prefixed cache; covered "
                    "by dry-run + hybrid tests")
    cache = init_cache(cfg, B, S + 1, enc_seq_local=cfg.enc_seq or 0,
                       dtype=jnp.float32)
    enc_len = None
    if cfg.family == "encdec":
        from repro.models.lm import prefill_cross_cache
        cache, _ = prefill_cross_cache(cfg, params, batch["frames"], cache)
        enc_len = cfg.enc_seq
    preds = []
    for t in range(S):
        nxt, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                 jnp.int32(t), enc_len=enc_len)
        preds.append(nxt)
    preds = jnp.concatenate(preds, axis=1)
    match = np.mean(np.asarray(preds) == np.asarray(fwd_next))
    assert match >= 0.95, f"decode/forward mismatch: {match}"
