"""MoE layer unit tests: routing/dispatch invariants + loader determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.axes import UNSHARDED
from repro.configs import get_config
from repro.models import moe as MOE


@pytest.fixture
def cfg():
    return get_config("qwen3-moe-235b-a22b").reduced()


def test_expert_capacity_rounding(cfg):
    c = MOE.expert_capacity(cfg, 1024)
    assert c % 128 == 0 or c == 8
    assert c >= 1024 * cfg.top_k / cfg.n_experts


def test_moe_block_shapes_and_finiteness(cfg):
    key = jax.random.PRNGKey(0)
    p = MOE.moe_params(key, cfg, cfg.n_experts)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, aux = MOE.moe_block(cfg, p, x, UNSHARDED)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.99  # switch aux loss lower bound is ~1 at balance


def test_moe_gate_weights_normalized(cfg):
    """Top-k gate weights renormalize to 1 per token."""
    key = jax.random.PRNGKey(1)
    p = MOE.moe_params(key, cfg, cfg.n_experts)
    x = jax.random.normal(key, (1, 8, cfg.d_model)).astype(jnp.float32)
    logits = jnp.einsum("td,de->te", x.reshape(-1, cfg.d_model), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / jnp.sum(gv, -1, keepdims=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(gv, -1)), 1.0, atol=1e-6)


def test_moe_respects_capacity_drop(cfg):
    """With capacity 8 and all tokens routed to one expert, only 8 survive."""
    cfg2 = dataclasses.replace(cfg, n_experts=2, top_k=1)
    key = jax.random.PRNGKey(2)
    p = MOE.moe_params(key, cfg2, cfg2.n_experts)
    # rig the router so every token picks expert 0
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jax.random.normal(key, (1, 64, cfg2.d_model))
    y, aux = MOE.moe_block(cfg2, p, x, UNSHARDED)
    # capacity = max(8, round128(64*1/2*1.25)) = 128 >= 64 -> nothing dropped
    nz = np.abs(np.asarray(y)).sum(-1) > 1e-7
    assert nz.mean() > 0.9
    # aux loss spikes under total imbalance (E * 1 * ~0.5)
    assert float(aux) > 0.9


def test_dense_residual_fused_psum_matches_unfused():
    """Arctic fusion (§Perf iter 1) must not change the math."""
    cfg = dataclasses.replace(get_config("arctic-480b").reduced(),
                              dense_ff=128)
    key = jax.random.PRNGKey(3)
    p = MOE.moe_params(key, cfg, cfg.n_experts)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    old = MOE._UNFUSED_DENSE
    try:
        MOE._UNFUSED_DENSE = False
        y_fused, _ = MOE.moe_block(cfg, p, x, UNSHARDED)
        MOE._UNFUSED_DENSE = True
        y_unfused, _ = MOE.moe_block(cfg, p, x, UNSHARDED)
    finally:
        MOE._UNFUSED_DENSE = old
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_unfused),
                               atol=1e-5, rtol=1e-5)


def test_loader_determinism_and_sharding():
    from repro.data.loader import LoaderConfig, PrefetchLoader, TokenStream
    cfg = LoaderConfig(global_batch=8, seq_len=16, vocab_size=100,
                       n_hosts=2, host_id=0, seed=7)
    s0 = TokenStream(cfg)
    s0b = TokenStream(cfg)
    a, _ = s0.batch_at(3)
    b, _ = s0b.batch_at(3)
    np.testing.assert_array_equal(a, b)          # deterministic
    cfg1 = dataclasses.replace(cfg, host_id=1)
    c, _ = TokenStream(cfg1).batch_at(3)
    assert not np.array_equal(a, c)              # hosts get different shards
    assert a.shape == (4, 16)                    # local = global / n_hosts

    pl = PrefetchLoader(s0, prefetch=2)
    batches = [next(pl) for _ in range(3)]
    pl.close()
    np.testing.assert_array_equal(batches[0][0], s0.batch_at(0)[0])
