"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="dev-only dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import (
    PrototypeMemory,
    combine,
    init_adaptive,
    kl_similarity,
    personalized_aggregate,
)
from repro.core.similarity import cosine_similarity, euclidean_similarity
from repro.evalreid import evaluate_retrieval
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import adam, apply_updates

_feat = hnp.arrays(np.float32, st.integers(2, 24),
                   elements=st.floats(-5, 5, width=32))


@settings(max_examples=40, deadline=None)
@given(_feat)
def test_kl_similarity_bounds_and_identity(x):
    a = jnp.asarray(x)
    s = float(kl_similarity(a, a))
    assert abs(s - 1.0) < 1e-4                      # Π(x, x) = 1
    b = a + 1.0                                     # softmax-invariant shift
    assert abs(float(kl_similarity(a, b)) - 1.0) < 1e-4


@settings(max_examples=40, deadline=None)
@given(_feat, st.floats(-5, 5, width=32))
def test_similarities_in_unit_interval(x, shift):
    a = jnp.asarray(x)
    b = a[::-1] + shift
    for fn in (kl_similarity, cosine_similarity, euclidean_similarity):
        s = float(fn(a, b))
        assert -1e-5 <= s <= 1.0 + 1e-5


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(1, 32))
def test_aggregation_convexity(c, p):
    """Row-stochastic W keeps aggregated params inside the convex hull."""
    rng = np.random.default_rng(0)
    thetas = [{"w": jnp.asarray(rng.standard_normal(p).astype(np.float32))}
              for _ in range(c)]
    W = rng.random((c, c)).astype(np.float32)
    np.fill_diagonal(W, 0)
    W = W / W.sum(1, keepdims=True)
    out = personalized_aggregate(thetas, W)
    stacked = np.stack([np.asarray(t["w"]) for t in thetas])
    lo, hi = stacked.min(0) - 1e-5, stacked.max(0) + 1e-5
    for o in out:
        v = np.asarray(o["w"])
        assert (v >= lo).all() and (v <= hi).all()


@settings(max_examples=25, deadline=None)
@given(hnp.arrays(np.float32, st.tuples(st.integers(1, 8), st.integers(1, 8)),
                  elements=st.floats(-10, 10, width=32)))
def test_combine_linearity(b):
    """theta(B, alpha, A) is affine: zero alpha -> A; zero A, unit alpha -> B."""
    B = jnp.asarray(b)
    ones, zeros = jnp.ones_like(B), jnp.zeros_like(B)
    # atol floor: XLA flushes subnormals to zero
    np.testing.assert_allclose(combine(B, ones, zeros), B, atol=1e-30)
    np.testing.assert_allclose(combine(B, zeros, B), B, atol=1e-30)
    ad = init_adaptive(B)
    np.testing.assert_allclose(ad.theta(), B, atol=1e-30)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(4, 40), st.integers(1, 10))
def test_memory_capacity_invariant(tasks, capacity, per_id):
    mem = PrototypeMemory(capacity=capacity, per_identity=per_id)
    rng = np.random.default_rng(0)
    for t in range(tasks):
        n = 12
        protos = rng.standard_normal((n, 4)).astype(np.float32)
        labels = rng.integers(0, 3, n)
        mem.add_task(protos, labels, protos, task_id=t)
        assert len(mem) <= capacity


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30))
def test_retrieval_perfect_and_random(q):
    """Queries identical to gallery entries retrieve themselves: mAP=R1=1."""
    rng = np.random.default_rng(q)
    feats = rng.standard_normal((q, 16)).astype(np.float32)
    ids = np.arange(q)
    m = evaluate_retrieval(feats, ids, feats, ids)
    assert m["R1"] == 1.0 and m["mAP"] >= 0.99


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_checkpoint_roundtrip(seed):
    rng = np.random.default_rng(seed)
    tree = {"a": {"w": jnp.asarray(rng.standard_normal((3, 4)).astype(np.float32))},
            "b": [jnp.arange(5), jnp.asarray(rng.standard_normal(2))]}
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, tree, metadata={"seed": seed})
        loaded, meta = load_checkpoint(path)
        assert meta["seed"] == seed
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
            np.testing.assert_allclose(a, b)


@settings(max_examples=40, deadline=None)
@given(st.sets(st.sampled_from(
    ["mesh", "in_specs", "out_specs", "auto", "check_rep", "check_vma"])),
    st.one_of(st.none(), st.booleans()))
def test_shard_map_shim_check_kwarg(extra_params, check_vma):
    """The compat shim maps check_vma onto whatever signature the resolved
    shard_map exposes: passthrough on the new layout, always-off check_rep
    on the 0.4.x layout, nothing when neither kwarg exists."""
    from repro.common.compat import adapt_check_kwarg
    params = frozenset({"f"} | extra_params)
    kw = adapt_check_kwarg(params, check_vma)
    if "check_vma" in params:
        assert kw == ({} if check_vma is None else {"check_vma": check_vma})
    elif "check_rep" in params:
        assert kw == {"check_rep": False}
    else:
        assert kw == {}
    assert set(kw) <= params


@settings(max_examples=20, deadline=None)
@given(st.booleans())
def test_shard_map_shim_resolves_both_layouts(new_layout):
    """resolve_shard_map finds shard_map on a new-layout module (public
    attribute) and falls back to jax.experimental on the old layout."""
    import types
    from repro.common.compat import resolve_shard_map
    sentinel = object()
    if new_layout:
        mod = types.SimpleNamespace(shard_map=sentinel)
        assert resolve_shard_map(mod) is sentinel
    else:
        mod = types.SimpleNamespace()        # 0.4.x: no jax.shard_map
        assert callable(resolve_shard_map(mod))


_payload = hnp.arrays(np.float32, st.integers(1, 200),
                      elements=st.floats(-100, 100, width=32))


@settings(max_examples=30, deadline=None)
@given(_payload)
def test_codec_raw_roundtrip_exact(x):
    """Lossless wire codec: decode(encode(tree)) is bit-exact and nbytes
    equals the dense payload size."""
    from repro.comm.codec import make_codec
    codec = make_codec("raw")
    tree = {"w": x}
    payload = codec.encode(tree)
    assert payload.nbytes == x.nbytes
    np.testing.assert_array_equal(codec.decode(payload)["w"], x)


@settings(max_examples=30, deadline=None)
@given(_payload)
def test_codec_int8_error_bound(x):
    """int8 stage: reconstruction error <= half a quantization step of
    each chunk's absmax."""
    from repro.comm.codec import make_codec
    codec = make_codec("int8", chunk=32)
    dec = codec.decode(codec.encode({"w": x}))["w"]
    n = x.size
    for o in range(0, n, 32):
        chunk = x[o:o + 32]
        bound = np.abs(chunk).max() / 127.0 * 0.5 + 1e-6
        assert np.abs(chunk - dec[o:o + 32]).max() <= bound


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float32, st.integers(8, 160),
                  elements=st.floats(-50, 50, width=32)),
       st.integers(1, 7))
def test_codec_grouped_topk_keeps_group_maxima(x, kg):
    """Stateless grouped top-k: within every group the surviving entries
    are the kg largest magnitudes, and the payload is deterministic."""
    from repro.comm.codec import grouped_topk_select_host
    v1, i1 = grouped_topk_select_host(x, 8, kg)
    v2, i2 = grouped_topk_select_host(x, 8, kg)
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(i1, i2)
    nb = (x.size + 7) // 8
    assert len(v1) == nb * kg
    xp = np.zeros((nb * 8,), np.float32)
    xp[:x.size] = x
    for b in range(nb):
        grp = np.abs(xp[b * 8:(b + 1) * 8])
        kept = i1[(i1 >= b * 8) & (i1 < (b + 1) * 8)] - b * 8
        assert len(kept) == kg
        dropped = np.delete(grp, kept)
        if dropped.size:
            assert grp[kept].min() >= dropped.max() - 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 4))
def test_codec_delta_stream_converges(seed, rounds):
    """delta+topk+int8 on a static stream: reconstruction error is
    non-increasing round over round (error feedback drains the residual)."""
    from repro.comm.codec import make_codec
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(256).astype(np.float32)
    codec = make_codec("topk+int8")
    errs = []
    for _ in range(rounds + 1):
        dec = codec.decode(codec.encode({"w": x}, peer=0), peer=0)
        errs.append(float(np.abs(dec["w"] - x).max()))
    assert errs[-1] <= errs[0] + 1e-6


def test_adam_decreases_quadratic():
    opt = adam(lr=0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-2 * l0
