"""Flash-attention backward kernel (custom_vjp, interpret mode) vs jax.grad
of the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as REF
from repro.kernels.flash_attention_bwd import flash_attention_vjp


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,H,S,hd", [(1, 2, 128, 64), (2, 1, 256, 32)])
def test_flash_attention_grads(B, H, S, hd, causal):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(k1, (B, H, S, hd))
    k = jax.random.normal(k2, (B, H, S, hd))
    v = jax.random.normal(k3, (B, H, S, hd))
    ct = jax.random.normal(k4, (B, H, S, hd))

    def loss_pallas(q, k, v):
        return jnp.sum(flash_attention_vjp(q, k, v, causal, 64, 64, True) * ct)

    def loss_ref(q, k, v):
        return jnp.sum(REF.flash_attention_ref(q, k, v, causal=causal) * ct)

    out_p = flash_attention_vjp(q, k, v, causal, 64, 64, True)
    out_r = REF.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               atol=2e-5, rtol=2e-5)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip(("dq", "dk", "dv"), gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4, err_msg=name)
