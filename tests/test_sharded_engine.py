"""engine="sharded" regression tests:

  (a) on a 1-device engine mesh with ``wire_dtype="float32"`` (bf16 wire
      cast off) the sharded engine is bit-tight against the stacked
      oracle: identical metrics and identical measured comm bytes;
  (b) the default bf16 wire keeps metrics within the measured deviation
      (~1.8e-3) of the stacked engine;
  (c) wire-codec runs measure identical bytes on both engines (the codec
      formulas and buffer shapes are leading-dim independent);
  (d) a zero-validity client row (mesh padding) is provably inert in the
      sharded server round: it never acquires ring history, its relevance
      row AND column stay zero, and nz leaves its base untouched;
  (e) a forced 8-device host mesh (subprocess: XLA_FLAGS must precede the
      jax import) with C=5 — clients NOT divisible by the device count —
      still matches the stacked oracle exactly at wire_dtype="float32".
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedSTIL
from repro.core import edge_model as EM
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.federated import run_simulation


@pytest.fixture(scope="module")
def bench():
    return FederatedReIDBenchmark(n_clients=3, n_tasks=3, n_identities=60,
                                  ids_per_task=10, samples_per_id=8, seed=1)


@pytest.fixture(scope="module")
def cfg(bench):
    return EdgeModelConfig(n_classes=bench.n_classes)


def _run(cfg, bench, engine, *, wire_dtype="bfloat16", codec=None):
    kw = {"codec": codec} if codec else {}
    return run_simulation(
        FedSTIL(cfg, n_clients=3, epochs=2, wire_dtype=wire_dtype, **kw),
        bench, rounds=4, eval_every=2, engine=engine)


# ---------------------------------------------------------------------------
# (a) 1-device mesh, f32 wire: bit-tight vs the stacked oracle
# ---------------------------------------------------------------------------


def test_sharded_matches_stacked_one_device(bench, cfg):
    stacked = _run(cfg, bench, "stacked", wire_dtype="float32")
    sharded = _run(cfg, bench, "sharded", wire_dtype="float32")
    for key in ("mAP", "R1", "R5", "forgetting_mAP"):
        assert abs(stacked.final(key) - sharded.final(key)) < 1e-6, key
    assert stacked.comm.total_c2s == sharded.comm.total_c2s
    assert stacked.comm.total_s2c == sharded.comm.total_s2c
    assert stacked.storage_bytes == sharded.storage_bytes


# ---------------------------------------------------------------------------
# (b) default bf16 wire: bounded deviation
# ---------------------------------------------------------------------------


def test_sharded_bf16_wire_close_to_stacked(bench, cfg):
    stacked = _run(cfg, bench, "stacked")
    sharded = _run(cfg, bench, "sharded")
    for key in ("mAP", "R1", "R5"):
        # measured max deviation 1.8e-3 on this benchmark (bf16 has ~3
        # decimal digits); byte accounting is exact either way
        assert abs(stacked.final(key) - sharded.final(key)) < 5e-3, key
    assert stacked.comm.total_c2s == sharded.comm.total_c2s
    assert stacked.comm.total_s2c == sharded.comm.total_s2c


# ---------------------------------------------------------------------------
# (c) codec runs: measured wire bytes identical on both engines
# ---------------------------------------------------------------------------


def test_sharded_codec_bytes_match_stacked(bench, cfg):
    stacked = _run(cfg, bench, "stacked", wire_dtype="float32",
                   codec="topk+int8")
    sharded = _run(cfg, bench, "sharded", wire_dtype="float32",
                   codec="topk+int8")
    assert stacked.comm.total_c2s == sharded.comm.total_c2s
    assert stacked.comm.total_s2c == sharded.comm.total_s2c
    for key in ("mAP", "R1"):
        assert abs(stacked.final(key) - sharded.final(key)) < 1e-6, key


def test_fedavg_sharded_matches_host(bench, cfg):
    from repro.federated import FedAvg
    host = run_simulation(FedAvg(cfg, epochs=2), bench, rounds=3,
                          eval_every=3)
    sharded = run_simulation(FedAvg(cfg, epochs=2), bench, rounds=3,
                             eval_every=3, engine="sharded")
    for key in ("mAP", "R1"):
        assert abs(host.final(key) - sharded.final(key)) < 1e-4, key
    assert host.comm.total_c2s == sharded.comm.total_c2s
    assert host.comm.total_s2c == sharded.comm.total_s2c


# ---------------------------------------------------------------------------
# (d) zero-validity rows are inert in the sharded server round
# ---------------------------------------------------------------------------


def test_sharded_server_round_zero_mask_row_inert(cfg):
    strat = FedSTIL(cfg, n_clients=4, epochs=1, wire_dtype="float32")
    strat.mesh = jax.make_mesh((1, 1), ("data", "model"))
    C = 4
    theta = jax.vmap(lambda k: EM.init_adaptive_layers(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), C))
    rng = np.random.default_rng(11)
    valid = jnp.asarray([1.0, 1.0, 1.0, 0.0], jnp.float32)
    for rnd in range(3):
        feats = jnp.asarray(rng.standard_normal((C, cfg.proto_dim)),
                            jnp.float32)
        out = strat.server_round_stacked(rnd, {"theta": theta,
                                               "task_feature": feats},
                                         valid=valid)
        nz = np.asarray(out["nz"])
        W = strat.last_W
        # the masked row never enters the ring: relevance row AND column
        # stay zero, so it neither receives nor donates a base
        assert not nz[3]
        assert (W[3] == 0).all() and (W[:, 3] == 0).all()
        if rnd > 0:
            assert nz[:3].all()


# ---------------------------------------------------------------------------
# (e) forced 8-device mesh, C=5 (not divisible): exact parity
# ---------------------------------------------------------------------------


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
assert jax.device_count() == 8, jax.device_count()

from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.federated import run_simulation

bench = FederatedReIDBenchmark(n_clients=5, n_tasks=2, n_identities=40,
                               ids_per_task=10, samples_per_id=6, seed=3)
cfg = EdgeModelConfig(n_classes=bench.n_classes)


def run(engine):
    res = run_simulation(FedSTIL(cfg, n_clients=5, epochs=1,
                                 wire_dtype="float32"), bench,
                         rounds=2, eval_every=2, engine=engine)
    return {"mAP": res.final("mAP"), "R1": res.final("R1"),
            "c2s": res.comm.total_c2s, "s2c": res.comm.total_s2c}


print(json.dumps({"stacked": run("stacked"), "sharded": run("sharded")}))
"""


def test_sharded_matches_stacked_on_forced_8_device_mesh():
    env = dict(os.environ)
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    st, sh = out["stacked"], out["sharded"]
    # C=5 pads to Cp=8 on the 8-device data axis; padding rows are masked
    # out of the ring and sliced out of eval/accounting, so the result is
    # the stacked oracle's, exactly
    assert abs(st["mAP"] - sh["mAP"]) < 1e-6
    assert abs(st["R1"] - sh["R1"]) < 1e-6
    assert st["c2s"] == sh["c2s"]
    assert st["s2c"] == sh["s2c"]
