"""Integration tests: federated lifelong simulation end-to-end, validating
the paper's ORDERING claims on the synthetic benchmark (see DESIGN.md §1 for
why absolute numbers are relative): FedSTIL learns, beats local-only, and
ablations hurt; comm accounting matches each strategy's declared payloads."""
import numpy as np
import pytest

from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.federated import FedAvg, FedCurv, FedProx, FedWeIT, run_simulation
from repro.lifelong import EWC, ICaRL, MAS, STL
from repro.core.edge_model import extract_prototypes


@pytest.fixture(scope="module")
def bench():
    return FederatedReIDBenchmark(n_clients=3, n_tasks=3, n_identities=60,
                                  ids_per_task=10, samples_per_id=8, seed=1)


@pytest.fixture(scope="module")
def cfg(bench):
    return EdgeModelConfig(n_classes=bench.n_classes)


def test_fedstil_learns(bench, cfg):
    res = run_simulation(FedSTIL(cfg, n_clients=3, epochs=3), bench,
                         rounds=6, eval_every=3)
    assert res.final("mAP") > 0.3
    assert res.final("R1") > 0.3


def test_fedstil_beats_stl(bench, cfg):
    stl = run_simulation(STL(cfg, epochs=3), bench, rounds=6, eval_every=6)
    fs = run_simulation(FedSTIL(cfg, n_clients=3, epochs=3), bench,
                        rounds=6, eval_every=6)
    assert fs.final("mAP") > stl.final("mAP") - 0.02


def test_all_strategies_run(bench, cfg):
    strategies = [
        STL(cfg, epochs=2), EWC(cfg, epochs=2), MAS(cfg, epochs=2),
        ICaRL(cfg, epochs=2, extractor=extract_prototypes),
        FedAvg(cfg, epochs=2), FedProx(cfg, epochs=2),
        FedCurv(cfg, epochs=2), FedWeIT(cfg, epochs=2, n_clients=3),
        FedSTIL(cfg, n_clients=3, epochs=2),
    ]
    for s in strategies:
        res = run_simulation(s, bench, rounds=3, eval_every=3)
        assert res.final("mAP") >= 0.0
        assert np.isfinite(res.final("mAP")), s.name


def test_comm_cost_ordering(bench, cfg):
    """Paper Table II: FedCurv moves ~3x FedAvg; local methods move 0."""
    stl = run_simulation(STL(cfg, epochs=2), bench, rounds=3, eval_every=3)
    avg = run_simulation(FedAvg(cfg, epochs=2), bench, rounds=3, eval_every=3)
    curv = run_simulation(FedCurv(cfg, epochs=2), bench, rounds=3, eval_every=3)
    fs = run_simulation(FedSTIL(cfg, n_clients=3, epochs=2), bench,
                        rounds=3, eval_every=3)
    assert stl.comm.total == 0
    assert avg.comm.total > 0
    assert curv.comm.total > 2.5 * avg.comm.total
    # FedSTIL C2S ~ theta + tiny task feature: close to FedAvg's
    assert fs.comm.total_c2s < 1.2 * avg.comm.total_c2s


def test_ablation_components_matter(bench, cfg):
    """Paper Table III: removing ST-integration hurts the most."""
    full = run_simulation(FedSTIL(cfg, n_clients=3, epochs=3), bench,
                          rounds=6, eval_every=6, seed=3)
    no_st = run_simulation(
        FedSTIL(cfg, n_clients=3, epochs=3, st_integration=False), bench,
        rounds=6, eval_every=6, seed=3)
    assert full.final("mAP") >= no_st.final("mAP") - 0.03


def test_fedstil_relevance_matrix(bench, cfg):
    s = FedSTIL(cfg, n_clients=3, epochs=2)
    run_simulation(s, bench, rounds=3, eval_every=3)
    W = s.last_W
    assert W is not None and W.shape == (3, 3)
    assert np.allclose(np.diag(W), 0.0)
    rows = W.sum(1)
    assert ((np.isclose(rows, 1.0, atol=1e-4)) | (rows == 0)).all()
