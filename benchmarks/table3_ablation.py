"""Paper Table III: ablation of ST-integration / prototype rehearsal /
parameter tying."""
from __future__ import annotations

from benchmarks.common import csv_row, run

VARIANTS = {
    "fedstil": {},
    "wo_st_integration": {"st_integration": False},
    "wo_prototype_rehearsal": {"rehearsal": False},
    "wo_parameter_tying": {"tying": False},
}


def main():
    print("variant,mAP,R1")
    out = {}
    for name, kw in VARIANTS.items():
        res, wall = run("fedstil", **kw)
        f = res.final_metrics()
        out[name] = f
        print(f"{name},{f['mAP']:.4f},{f['R1']:.4f}", flush=True)
        csv_row(f"table3/{name}", wall, f"mAP={f['mAP']:.4f}")
    return out


if __name__ == "__main__":
    main()
