"""Shared benchmark harness: the paper's experimental setup (5 clients x 6
tasks, 60/40 split), sized to run on CPU in minutes. Every table/figure
script prints CSV rows ``name,us_per_call,derived`` plus its table."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.comm.accounting import fmt_bytes
from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig, extract_prototypes
from repro.data import FederatedReIDBenchmark
from repro.federated import FedAvg, FedCurv, FedProx, FedWeIT, run_simulation
from repro.lifelong import EWC, ICaRL, MAS, STL

N_CLIENTS = 5
N_TASKS = 6
ROUNDS = 12          # 2 rounds per task (paper: 60; scaled for CPU)
EVAL_EVERY = 4
EPOCHS = 4


@functools.lru_cache(maxsize=4)
def benchmark(seed: int = 0) -> FederatedReIDBenchmark:
    return FederatedReIDBenchmark(
        n_clients=N_CLIENTS, n_tasks=N_TASKS, n_identities=150,
        ids_per_task=16, samples_per_id=8, seed=seed)


def edge_cfg(bench) -> EdgeModelConfig:
    return EdgeModelConfig(n_classes=bench.n_classes)


def make_strategy(name: str, cfg, **kw):
    table = {
        "stl": lambda: STL(cfg, epochs=EPOCHS),
        "ewc": lambda: EWC(cfg, epochs=EPOCHS),
        "mas": lambda: MAS(cfg, epochs=EPOCHS),
        "icarl": lambda: ICaRL(cfg, epochs=EPOCHS, extractor=extract_prototypes),
        "fedavg": lambda: FedAvg(cfg, epochs=EPOCHS),
        "fedprox": lambda: FedProx(cfg, epochs=EPOCHS),
        "fedcurv": lambda: FedCurv(cfg, epochs=EPOCHS),
        "fedweit_a": lambda: FedWeIT(cfg, epochs=EPOCHS, n_clients=N_CLIENTS,
                                     l1=1e-4, l2=1e-6),
        "fedweit_b": lambda: FedWeIT(cfg, epochs=EPOCHS, n_clients=N_CLIENTS,
                                     l1=5e-6, l2=1e-3),
        "fedstil": lambda: FedSTIL(cfg, epochs=EPOCHS, n_clients=N_CLIENTS, **kw),
    }
    if name not in table:
        return FedSTIL(cfg, epochs=EPOCHS, n_clients=N_CLIENTS, **kw)
    return table[name]()


def run(name: str, *, rounds=ROUNDS, seed=0, verbose=False, **kw):
    bench = benchmark(seed)
    cfg = edge_cfg(bench)
    strat = make_strategy(name, cfg, **kw)
    t0 = time.time()
    res = run_simulation(strat, bench, rounds=rounds, eval_every=EVAL_EVERY,
                         seed=seed, verbose=verbose)
    wall = time.time() - t0
    return res, wall


def csv_row(name: str, wall_s: float, derived: str):
    print(f"{name},{wall_s * 1e6:.0f},{derived}", flush=True)


def mesh_metadata() -> dict:
    """Device/mesh environment stamped into every BENCH_*.json metadata
    block: backend, device count, and the engine mesh this process would
    build — so a perf trajectory is never compared across unequal meshes
    (an 8-device forced host run and a 1-device run are different
    experiments)."""
    import jax

    from repro.sharding import specs as shard_specs
    mesh = shard_specs.engine_mesh()
    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "mesh": {"axes": list(mesh.axis_names),
                 "shape": [int(mesh.shape[a]) for a in mesh.axis_names]},
    }
