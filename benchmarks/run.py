"""Benchmark orchestrator: one module per paper table/figure.

``python -m benchmarks.run [--only tableN]`` prints each table plus
``name,us_per_call,derived`` CSV rows. ``--bench server`` runs the
host-vs-stacked server-round sweep (``BENCH_server_round.json``);
``--bench eval`` runs the host-vs-batched eval-round sweep
(``BENCH_eval_round.json``); ``--bench comm`` runs the wire-codec
host-loop-vs-batched encode/decode sweep (``BENCH_comm_round.json``);
``--bench mesh`` runs the stacked-vs-sharded server-round C→10k scaling
sweep on a forced 8-device host mesh (``BENCH_mesh_round.json``);
``--bench serve`` runs the online-retrieval QPS/p99 sweep over gallery
sizes, int8 vs fp32 vs a naive per-query loop
(``BENCH_serve_round.json``) — the machine-readable perf trajectories
future PRs regress against.

Every ``--bench`` run executes under a live ``repro.obs`` tracer and
stamps the run's ``telemetry`` block (span/metric counts, per-phase and
per-stage time breakdown) into the ``BENCH_*.json`` it wrote; the server
bench additionally carries the measured tracing-overhead gate.
"""
import argparse
import sys
import time

_BENCH_OUT = {
    "server": "BENCH_server_round.json",
    "eval": "BENCH_eval_round.json",
    "comm": "BENCH_comm_round.json",
    "mesh": "BENCH_mesh_round.json",
    "serve": "BENCH_serve_round.json",
}


def _run_bench_traced(name: str, fn) -> None:
    """Run one perf bench under a live tracer, then stamp the telemetry
    block into the BENCH_*.json the bench wrote (keys a bench already
    stamped itself — e.g. the server bench's overhead gate — win)."""
    import json
    from pathlib import Path

    from repro.obs import trace as obs
    from repro.obs.report import telemetry_block

    tracer = obs.Tracer()
    with obs.active(tracer):
        fn()
    out = Path(__file__).resolve().parent.parent / _BENCH_OUT[name]
    if not out.exists():
        return
    payload = json.loads(out.read_text())
    block = telemetry_block(tracer.events)
    existing = payload.get("telemetry")
    if existing:
        for k, v in block.items():
            existing.setdefault(k, v)
    else:
        payload["telemetry"] = block
    out.write_text(json.dumps(payload, indent=2) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table2|table3|table4|table5|table6|fig6|fig8|kernels")
    ap.add_argument("--bench", default=None,
                    choices=["server", "eval", "comm", "mesh", "serve"],
                    help="perf-trajectory benches (JSON output)")
    args = ap.parse_args()

    if args.bench == "server":
        from benchmarks.server_round import main as server_main
        _run_bench_traced("server", server_main)
        if args.only is None:
            return
    if args.bench == "eval":
        from benchmarks.eval_round import bench_eval_round
        _run_bench_traced("eval", bench_eval_round)
        if args.only is None:
            return
    if args.bench == "comm":
        from benchmarks.comm_round import bench_comm_round
        _run_bench_traced("comm", bench_comm_round)
        if args.only is None:
            return
    if args.bench == "mesh":
        # mesh_round sets XLA_FLAGS at import time, before jax loads
        from benchmarks.mesh_round import bench_mesh_round
        _run_bench_traced("mesh", bench_mesh_round)
        if args.only is None:
            return
    if args.bench == "serve":
        from benchmarks.serve_bench import bench_serve
        _run_bench_traced("serve", bench_serve)
        if args.only is None:
            return

    from benchmarks import (fig6_rounds, fig8_comm, kernels_bench,
                            table2_methods, table3_ablation, table4_memory,
                            table5_backbones, table6_distance)
    suites = {
        "table2": table2_methods.main,
        "table3": table3_ablation.main,
        "table4": table4_memory.main,
        "table5": table5_backbones.main,
        "table6": table6_distance.main,
        "fig6": fig6_rounds.main,
        "fig8": fig8_comm.main,
        "kernels": kernels_bench.main,
    }
    names = [args.only] if args.only else list(suites)
    t0 = time.time()
    for name in names:
        print(f"\n===== {name} =====", flush=True)
        suites[name]()
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s", flush=True)


if __name__ == '__main__':
    main()
