"""Paper Table IV: forgetting vs rehearsal memory size (mAP-F, R1-F, R5-F
decrease as the prototype memory grows)."""
from __future__ import annotations

from benchmarks.common import csv_row, run
from repro.comm.accounting import fmt_bytes

SIZES = [0, 250, 500, 1000, 2000]


def main():
    print("memory_size,storage,mAP_F,R1_F,R5_F")
    out = {}
    for size in SIZES:
        kw = ({"rehearsal": False} if size == 0
              else {"memory_size": size})
        res, wall = run("fedstil", **kw)
        f = res.final_metrics()
        out[size] = f
        print(f"{size},{fmt_bytes(res.storage_bytes)},"
              f"{f['forgetting_mAP']:.4f},{f['forgetting_R1']:.4f},"
              f"{f.get('forgetting_R5', 0.0):.4f}", flush=True)
        csv_row(f"table4/mem{size}", wall,
                f"R1_F={f['forgetting_R1']:.4f}")
    return out


if __name__ == "__main__":
    main()
