"""Roofline report: reads results/dryrun/*.json (written by
repro.launch.dryrun) and prints the §Roofline table — three terms per
(arch x shape) on the single-pod mesh, dominant bottleneck, MODEL_FLOPS
ratio. Also emits the EXPERIMENTS.md-ready markdown with --md."""
from __future__ import annotations

import argparse
import glob
import json
import os

ROW = ("{arch},{shape},{t_comp:.6f},{t_mem:.6f},{t_coll:.6f},{bottleneck},"
       "{useful:.4f}")


def load(out_dir: str, mesh: str = "sp"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()

    recs = [r for r in load(args.out) if r.get("ok")]
    fails = [r for r in load(args.out) if not r.get("ok")]
    if args.md:
        print("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
              "| bottleneck | useful FLOPs ratio |")
        print("|---|---|---|---|---|---|---|")
        for r in recs:
            rf = r["roofline"]
            print(f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.4g} "
                  f"| {rf['t_memory_s']:.4g} | {rf['t_collective_s']:.4g} "
                  f"| **{rf['bottleneck']}** | {rf['useful_flops_ratio']:.3f} |")
    else:
        print("arch,shape,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
              "useful_flops_ratio")
        for r in recs:
            rf = r["roofline"]
            print(ROW.format(arch=r["arch"], shape=r["shape"],
                             t_comp=rf["t_compute_s"], t_mem=rf["t_memory_s"],
                             t_coll=rf["t_collective_s"],
                             bottleneck=rf["bottleneck"],
                             useful=rf["useful_flops_ratio"]))
    if fails:
        print(f"# FAILURES: {[(r['arch'], r['shape']) for r in fails]}")


if __name__ == "__main__":
    main()
