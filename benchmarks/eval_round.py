"""Per-eval-round wall time: host Python loop vs the batched device program.

The host baseline is a faithful replica of the pre-PR ``_eval_round``: per
client it re-extracts the gallery prototypes (``EM.extract_prototypes`` on
the raw gallery every eval round), runs the eager per-client feature head
(which materialises the unused classifier logits — eager jax cannot DCE
them), and per trained task runs one more feature dispatch plus a numpy
``evaluate_retrieval`` — O(C·T) host iterations per eval round. The device
path is this PR's ``_eval_round_device``: padded (C, T, Q, D) query stacks,
gallery prototypes cached across rounds, vmapped feature heads, all
distance matrices through the kernels/pairwise_dist path, and sort-free
mAP/CMC + forgetting inputs in ONE jitted program, with only the
(C, T, metrics) result read back. ``host_cached_ms`` additionally reports
the PR's improved host path (gallery prototype cache, satellite task) so
the JSON separates the caching win from the batching win.

``python -m benchmarks.run --bench eval`` sweeps C ∈ {5, 20, 100} and
writes ``BENCH_eval_round.json`` (repo root). ``--smoke`` (used by
``scripts/run_tier1.sh --smoke``) runs a single C=5 eval as a wiring check.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import edge_model as EM
from repro.core.edge_model import EdgeModelConfig
from repro.data.synthetic import FederatedReIDBenchmark
from repro.evalreid import evaluate_retrieval
from repro.federated.simulation import (_EvalCache, _eval_round,
                                        _eval_round_device,
                                        _pre_extract_prototypes)
from repro.lifelong import STL
from repro.train.metrics import LifelongTracker

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_eval_round.json"


def _setup(C: int, n_tasks: int):
    bench = FederatedReIDBenchmark(n_clients=C, n_tasks=n_tasks,
                                   n_identities=max(400, 10 * C),
                                   ids_per_task=6, samples_per_id=4, seed=0)
    cfg = EdgeModelConfig(n_classes=bench.n_classes)
    strat = STL(cfg)
    key = jax.random.PRNGKey(0)
    g_key, *client_keys = jax.random.split(key, C + 1)
    g_params = EM.init_extraction(g_key, cfg)
    states = {c: strat.init_client(client_keys[c]) for c in range(C)}
    protos = _pre_extract_prototypes(bench, g_params)
    cache = _EvalCache(bench, protos)
    return bench, strat, states, g_params, protos, cache


def _eval_round_pre_pr(strategy, states, bench, g_params, protos, tracker,
                       rnd, t):
    """The pre-PR host eval loop, verbatim: gallery prototypes re-extracted
    every round, eager per-client features, numpy metrics per (c, t)."""
    for c in range(bench.n_clients):
        state = states[c]
        gal_x, gal_y = bench.gallery(c, t)
        gal_p = np.asarray(EM.extract_prototypes(g_params, gal_x))
        gal_f = strategy.features(state, gal_p)
        for tt in range(t + 1):
            _, _, qx, qy = protos[(c, tt)]
            qf = strategy.features(state, qx)
            m = evaluate_retrieval(qf, qy, gal_f, gal_y)
            tracker.record(c, tt, rnd, m)


def _time(fn, iters):
    fn(0)                                    # warmup (jit compile / caches)
    t0 = time.perf_counter()
    for r in range(1, iters + 1):
        fn(r)
    return (time.perf_counter() - t0) / iters


def bench_eval_round(Cs=(5, 20, 100), *, n_tasks=2, iters=4,
                     out=DEFAULT_OUT):
    cases = []
    print("C,host_ms,host_cached_ms,device_ms,speedup")
    for C in Cs:
        bench, strat, states, g_params, protos, cache = _setup(C, n_tasks)
        t = n_tasks - 1                      # all tasks trained: worst case

        tr_h = LifelongTracker(C)
        host_s = _time(lambda r: _eval_round_pre_pr(
            strat, states, bench, g_params, protos, tr_h, r, t), iters)
        tr_c = LifelongTracker(C)
        cached_s = _time(lambda r: _eval_round(
            strat, lambda c: states[c], bench, cache, tr_c, r, t), iters)
        # host-engine device path: restacks the eval thetas each round (the
        # stacked engine keeps them resident and is strictly cheaper)
        tr_d = LifelongTracker(C)
        dev_s = _time(lambda r: _eval_round_device(
            strat, strat.stack_eval_thetas(states), cache, tr_d, r, t),
            iters)

        # same tracker metrics from all paths (allclose guard, not a perf op)
        for key in ("mAP", "R1", "R3", "R5"):
            np.testing.assert_allclose(tr_h.mean_accuracy(iters, key),
                                       tr_d.mean_accuracy(iters, key),
                                       atol=2e-3)
            np.testing.assert_allclose(tr_c.mean_accuracy(iters, key),
                                       tr_d.mean_accuracy(iters, key),
                                       atol=2e-3)
        case = {"C": C, "gallery_rows": int(cache.g_max),
                "max_matches": int(cache.max_matches),
                "host_ms": host_s * 1e3, "host_cached_ms": cached_s * 1e3,
                "device_ms": dev_s * 1e3, "speedup": host_s / dev_s}
        cases.append(case)
        print(f"{C},{case['host_ms']:.2f},{case['host_cached_ms']:.2f},"
              f"{case['device_ms']:.2f},{case['speedup']:.1f}x", flush=True)
    from benchmarks.common import mesh_metadata
    from repro.analysis.registry import coverage
    cov = coverage()
    payload = {
        "bench": "eval_round",
        "env": mesh_metadata(),
        "config": {"n_tasks": n_tasks, "iters": iters,
                   "backend": jax.default_backend()},
        "analysis_coverage": {k: cov[k] for k in ("programs_registered",
                                                  "programs_traced")},
        "cases": cases,
    }
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    return payload


def smoke():
    """One C=5 eval round on both paths (the run_tier1.sh --smoke hook)."""
    bench, strat, states, g_params, protos, cache = _setup(5, 2)
    theta = strat.stack_eval_thetas(states)
    tr_h, tr_d = LifelongTracker(5), LifelongTracker(5)
    _eval_round(strat, lambda c: states[c], bench, cache, tr_h, 0, 1)
    _eval_round_device(strat, theta, cache, tr_d, 0, 1)
    for key in ("mAP", "R1"):
        np.testing.assert_allclose(tr_h.mean_accuracy(0, key),
                                   tr_d.mean_accuracy(0, key), atol=2e-3)
    print(f"eval smoke OK: device mAP={tr_d.mean_accuracy(0, 'mAP'):.4f} "
          f"== host mAP={tr_h.mean_accuracy(0, 'mAP'):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single C=5 eval round (wiring check, no JSON)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        bench_eval_round()


if __name__ == "__main__":
    main()
