"""Paper Table V: different backbones (ResNet18/ResNet50/Swin-T there; here
the backbone-agnosticism is exercised with three extraction/adaptive widths
standing in for small/medium/large backbones, plus the assigned-architecture
smoke path at transformer scale)."""
from __future__ import annotations

import time

from benchmarks.common import EPOCHS, N_CLIENTS, ROUNDS, benchmark, csv_row
from repro.comm.accounting import fmt_bytes
from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.federated import FedAvg, run_simulation
from repro.lifelong import EWC

BACKBONES = {
    "small(resnet18-like)": dict(proto_dim=64, hidden=64, feat_dim=32),
    "medium(resnet50-like)": dict(proto_dim=128, hidden=128, feat_dim=64),
    "large(swin-t-like)": dict(proto_dim=256, hidden=256, feat_dim=128),
}


def main():
    print("backbone,method,mAP,R1,storage,total_comm")
    bench = benchmark(0)
    out = {}
    for bk_name, dims in BACKBONES.items():
        cfg = EdgeModelConfig(n_classes=bench.n_classes, **dims)
        for method, ctor in [
            ("fedavg", lambda: FedAvg(cfg, epochs=EPOCHS)),
            ("fedstil", lambda: FedSTIL(cfg, epochs=EPOCHS,
                                        n_clients=N_CLIENTS)),
        ]:
            t0 = time.time()
            res = run_simulation(ctor(), bench, rounds=ROUNDS, eval_every=4)
            f = res.final_metrics()
            out[(bk_name, method)] = f
            print(f"{bk_name},{method},{f['mAP']:.4f},{f['R1']:.4f},"
                  f"{fmt_bytes(res.storage_bytes)},{fmt_bytes(res.comm.total)}",
                  flush=True)
            csv_row(f"table5/{bk_name}/{method}", time.time() - t0,
                    f"mAP={f['mAP']:.4f}")
    return out


if __name__ == "__main__":
    main()
