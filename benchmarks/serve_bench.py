"""Serving bench: QPS + latency percentiles vs gallery size, int8 vs fp32.

Three paths over the same resident ``GalleryIndex`` (repro.serving):

  * ``int8``  — the fast path: continuous-batched queries against the
    int8-quantized index via the ``batched_int8_pairwise_dist`` kernel;
  * ``fp32``  — the exact batched path (only fits the device budget up to
    a quarter of the int8 gallery);
  * ``naive`` — one fp32 device dispatch per query (the pre-serving
    baseline the batched paths must beat ≥2x at the largest gallery).

Capacity is framed against a declared per-client device budget for the
gallery feature payload (``BUDGET_BYTES`` = 8 MiB): fp32 rows cost
4*feat_dim bytes -> 32768 rows; int8 rows cost feat_dim bytes -> 131072
rows (the 4x the quantize kernel buys; total resident bytes including the
scale/norm/id sidecars are reported too, ~3.5x). The sweep tops out at
the int8-enabled maximum, where fp32 cannot follow.

Fidelity: on the synthetic ReID bench (the eval stack's ``_EvalCache``
galleries, C=5, T=2), both paths rank every query over the FULL gallery
(k=G) and the mAP delta int8-vs-fp32 must stay within ``MAP_TOLERANCE``;
the fp32 path must match the numpy host oracle's ranking exactly.

``python -m benchmarks.run --bench serve`` writes ``BENCH_serve_round.json``
(repo root). ``--smoke`` (used by ``scripts/run_tier1.sh --smoke``) runs a
tiny gallery end-to-end with the same parity asserts, no JSON.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import edge_model as EM
from repro.serving import (ContinuousBatcher, GalleryIndex, RetrievalEngine,
                           map_from_ranked_ids, run_closed_loop,
                           run_open_loop)

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_serve_round.json"

BUDGET_BYTES = 8 << 20            # per-client gallery feature payload budget
MAP_TOLERANCE = 0.01              # declared int8-vs-fp32 mAP tolerance
_CFG = EM.EdgeModelConfig()
G_FP32_MAX = BUDGET_BYTES // (4 * _CFG.feat_dim)     # 32768
G_INT8_MAX = BUDGET_BYTES // _CFG.feat_dim           # 131072


def _stack_thetas(C: int, seed: int, cfg=_CFG):
    keys = jax.random.split(jax.random.PRNGKey(seed), C)
    thetas = [EM.init_adaptive_layers(k, cfg) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *thetas)


def _mk_engine(C: int, G: int, mode: str, *, k: int, seed: int = 0,
               keep_fp32: bool = None):
    rng = np.random.default_rng(seed)
    protos = [rng.standard_normal((G, _CFG.proto_dim)).astype(np.float32)
              for _ in range(C)]
    ids = [np.arange(G, dtype=np.int32) for _ in range(C)]
    index = GalleryIndex(protos, ids,
                         keep_fp32=(mode == "fp32") if keep_fp32 is None
                         else keep_fp32)
    return RetrievalEngine(index, _stack_thetas(C, seed), k=k, mode=mode), rng


def _mk_stream(rng, C: int, n: int):
    return [(int(rng.integers(C)),
             rng.standard_normal(_CFG.proto_dim).astype(np.float32), -1)
            for _ in range(n)]


def _strip(r):
    return {k: v for k, v in r.items() if k != "tickets"}


def _measure_batched(engine, rng, *, batch: int, n_queries: int):
    batcher = ContinuousBatcher(engine, batch=batch)
    C = engine.index.n_clients
    batcher.submit(0, _mk_stream(rng, C, 1)[0][1])
    batcher.drain()                                    # compile warmup
    closed = _strip(run_closed_loop(batcher, _mk_stream(rng, C, n_queries)))
    rate = 0.6 * closed["qps"]
    open_ = _strip(run_open_loop(batcher, _mk_stream(rng, C, n_queries // 2),
                                 rate))
    return {"closed": closed, "open": open_}


def _measure_naive(engine, rng, *, n_queries: int):
    C = engine.index.n_clients
    stream = _mk_stream(rng, C, n_queries)
    engine.query_naive(stream[0][0], stream[0][1])     # compile warmup
    lat = []
    t0 = time.perf_counter()
    for client, proto, _ in stream:
        t1 = time.perf_counter()
        engine.query_naive(client, proto)
        lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    lat = np.array(lat)
    return {"n": n_queries, "wall_s": wall, "qps": n_queries / wall,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3)}


def _fidelity(C=5, n_tasks=2):
    """mAP over the synthetic ReID bench's eval galleries, full-gallery
    ranking per path; plus exact fp32-vs-host-oracle rank parity."""
    from benchmarks.eval_round import _setup
    _, strat, states, _, protos, cache = _setup(C, n_tasks)
    theta = strat.stack_eval_thetas(states)
    t = n_tasks - 1
    eng8 = RetrievalEngine.from_eval_cache(theta, cache, t, mode="int8",
                                           keep_fp32=True)
    engf = RetrievalEngine(eng8.index, theta, mode="fp32")
    G = eng8.index.capacity
    maps = {"int8": [], "fp32": []}
    parity = True
    for tt in range(t + 1):
        qp = np.stack([protos[(c, tt)][2] for c in range(C)])   # (C, Q, D)
        qids = np.stack([protos[(c, tt)][3] for c in range(C)])
        qmask = np.ones(qp.shape[:2], np.float32)
        ids8, _ = eng8.query_batch(qp, qmask, k=G)
        idsf, _ = engf.query_batch(qp, qmask, k=G)
        idsh, _ = engf.query_host(qp, qmask, k=G)
        parity = parity and bool(np.array_equal(idsf, idsh))
        for c in range(C):
            maps["int8"].append(map_from_ranked_ids(ids8[c], qids[c]))
            maps["fp32"].append(map_from_ranked_ids(idsf[c], qids[c]))
    m8 = float(np.mean(maps["int8"]))
    mf = float(np.mean(maps["fp32"]))
    return {"bench": f"synthetic C={C} T={n_tasks} (eval-cache galleries)",
            "gallery_rows": int(G), "map_fp32": mf, "map_int8": m8,
            "map_delta": abs(mf - m8), "tolerance": MAP_TOLERANCE,
            "within_tolerance": bool(abs(mf - m8) <= MAP_TOLERANCE),
            "fp32_rank_parity_vs_host_oracle": parity}


def bench_serve(Gs=(4096, 16384, G_FP32_MAX, G_INT8_MAX), *, C=4, batch=64,
                k=10, n_queries=512, n_naive=48, out=DEFAULT_OUT):
    cases = []
    print("G,int8_qps,fp32_qps,naive_qps,int8_p99_ms,speedup_vs_naive")
    for G in Gs:
        fits_fp32 = G <= G_FP32_MAX
        # one index serves every path; fp32 rows kept as the naive/exact
        # operand (beyond G_FP32_MAX that violates the declared budget —
        # flagged, kept only so the baseline exists to be beaten)
        eng8, rng = _mk_engine(C, G, "int8", k=k, keep_fp32=True)
        int8 = _measure_batched(eng8, rng, batch=batch, n_queries=n_queries)
        fp32 = None
        if fits_fp32:
            engf = RetrievalEngine(eng8.index, eng8.theta, k=k, mode="fp32")
            fp32 = _measure_batched(engf, rng, batch=batch,
                                    n_queries=n_queries)
        else:
            engf = RetrievalEngine(eng8.index, eng8.theta, k=k, mode="fp32")
        naive = _measure_naive(engf, rng, n_queries=n_naive)
        case = {
            "G": int(G), "fits_fp32_budget": fits_fp32,
            "resident_bytes_int8": eng8.index.resident_bytes("int8"),
            "resident_bytes_fp32": eng8.index.resident_bytes("fp32"),
            "int8": int8, "fp32": fp32, "naive_fp32": naive,
            "speedup_vs_naive": int8["closed"]["qps"] / naive["qps"],
        }
        cases.append(case)
        fqps = f"{fp32['closed']['qps']:.0f}" if fp32 else "-"
        print(f"{G},{int8['closed']['qps']:.0f},{fqps},{naive['qps']:.0f},"
              f"{int8['closed']['p99_ms']:.2f},"
              f"{case['speedup_vs_naive']:.1f}x", flush=True)

    fid = _fidelity()
    assert fid["fp32_rank_parity_vs_host_oracle"], \
        "serving fp32 path diverged from the numpy oracle"
    assert fid["within_tolerance"], \
        f"int8 mAP delta {fid['map_delta']:.4f} > {MAP_TOLERANCE}"
    print(f"fidelity: mAP fp32={fid['map_fp32']:.4f} "
          f"int8={fid['map_int8']:.4f} delta={fid['map_delta']:.4f} "
          f"(tol {MAP_TOLERANCE})")

    from benchmarks.common import mesh_metadata
    from repro.analysis.registry import coverage
    cov = coverage()
    payload = {
        "bench": "serve_round",
        "env": mesh_metadata(),
        "config": {"C": C, "batch": batch, "k": k, "n_queries": n_queries,
                   "n_naive": n_naive, "backend": jax.default_backend(),
                   "budget_bytes_per_client": BUDGET_BYTES,
                   "feat_dim": _CFG.feat_dim},
        "capacity": {"fp32_rows_max": G_FP32_MAX,
                     "int8_rows_max": G_INT8_MAX,
                     "row_capacity_ratio": G_INT8_MAX / G_FP32_MAX},
        "analysis_coverage": {key: cov[key] for key in
                              ("programs_registered", "programs_traced")},
        "cases": cases,
        "fidelity": fid,
    }
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    return payload


def smoke():
    """Tiny end-to-end serve (run_tier1.sh --smoke hook): int8 + naive
    paths, exact fp32-vs-oracle parity, no JSON."""
    C, G = 3, 512
    eng8, rng = _mk_engine(C, G, "int8", k=5, keep_fp32=True)
    int8 = _measure_batched(eng8, rng, batch=16, n_queries=96)
    engf = RetrievalEngine(eng8.index, eng8.theta, k=5, mode="fp32")
    naive = _measure_naive(engf, rng, n_queries=24)
    qp = rng.standard_normal((C, 4, _CFG.proto_dim)).astype(np.float32)
    qmask = np.ones((C, 4), np.float32)
    ids_d, _ = engf.query_batch(qp, qmask)
    ids_h, _ = engf.query_host(qp, qmask)
    assert np.array_equal(ids_d, ids_h), "fp32 serving != numpy oracle"
    print(f"serve smoke OK: G={G} int8 QPS={int8['closed']['qps']:.0f} "
          f"(p99={int8['closed']['p99_ms']:.2f}ms) naive "
          f"QPS={naive['qps']:.0f}; fp32 ids == host oracle")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny gallery end-to-end (wiring check, no JSON)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        bench_serve()


if __name__ == "__main__":
    main()
