"""Serving bench: QPS + latency percentiles vs gallery size, int8/fp32/ivf.

Four paths over the same resident ``GalleryIndex`` (repro.serving):

  * ``int8``  — the exact fast path: continuous-batched queries against
    the int8-quantized index via ``batched_int8_pairwise_dist`` (scores
    all G rows; the recall oracle for ivf);
  * ``ivf``   — the approximate path: nprobe nearest coarse buckets via
    ``batched_cluster_assign`` + ``batched_ivf_shortlist`` (scores
    nprobe*bcap rows, ~sqrt(G)-fold less GEMM at nlist ~ sqrt(G)); swept
    over nprobe with recall@k + mAP@k delta measured vs the int8 path;
  * ``fp32``  — the exact batched path (only fits the device budget up
    to a quarter of the int8 gallery);
  * ``naive`` — one fp32 device dispatch per query (the pre-serving
    baseline the batched paths must beat ≥2x at the largest gallery).

Gallery content is CLUSTERED, not isotropic: rows sit around unit id
centers drawn in a rank-16 subspace of prototype space (8 rows per id,
perturbation norm rho=0.22), mirroring real ReID embeddings' fast
spectral decay — on isotropic 64-d data every bucket is equidistant and
NO shortlist can recall (measured ~0.4 at G=131k); on clustered data the
coarse quantizer is meaningful and recall is honestly measurable. Row
ids are unique (row index), so recall@k is row-exact; person identity
for mAP is id // 8.

Capacity is framed against a declared per-client device budget for the
gallery feature payload (``BUDGET_BYTES`` = 8 MiB): fp32 rows cost
4*feat_dim bytes -> 32768 rows; int8 rows cost feat_dim bytes -> 131072
rows (the 4x the quantize kernel buys). The ivf image re-spends ~1.4x
the int8 row bytes (bucket padding) plus the small coarse quantizer —
reported as ``resident_bytes_ivf``.

Fidelity: on the synthetic ReID bench (the eval stack's ``_EvalCache``
galleries, C=5, T=2), int8 and fp32 rank every query over the FULL
gallery (k=G) and the mAP delta must stay within ``MAP_TOLERANCE``; the
fp32 path must match the numpy host oracle's ranking exactly. The ivf
acceptance gate at G = int8 max: closed-loop QPS ≥ 4x the exact int8
path with recall@10 ≥ 0.95 at the default nprobe.

``python -m benchmarks.run --bench serve`` writes ``BENCH_serve_round.json``
(repo root). ``--smoke`` (used by ``scripts/run_tier1.sh --smoke``) runs a
tiny gallery end-to-end with the same parity asserts, no JSON.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import edge_model as EM
from repro.serving import (ContinuousBatcher, GalleryIndex, RetrievalEngine,
                           map_from_ranked_ids, recall_at_k, run_closed_loop,
                           run_open_loop)

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_serve_round.json"

BUDGET_BYTES = 8 << 20            # per-client gallery feature payload budget
MAP_TOLERANCE = 0.01              # declared int8-vs-fp32 mAP tolerance
_CFG = EM.EdgeModelConfig()
G_FP32_MAX = BUDGET_BYTES // (4 * _CFG.feat_dim)     # 32768
G_INT8_MAX = BUDGET_BYTES // _CFG.feat_dim           # 131072

# clustered-gallery shape (see module docstring) + ivf acceptance gate
N_PER_ID = 8
ID_RANK = 16
ID_RHO = 0.22
NPROBE_DEFAULT = 8
NPROBE_SWEEP = (4, 8, 16)
IVF_MIN_RECALL = 0.95
IVF_MIN_SPEEDUP = 4.0


def _stack_thetas(C: int, seed: int, cfg=_CFG):
    keys = jax.random.split(jax.random.PRNGKey(seed), C)
    thetas = [EM.init_adaptive_layers(k, cfg) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *thetas)


def _l2n(x):
    return x / np.sqrt(np.maximum((x * x).sum(-1, keepdims=True), 1e-12))


def _clustered_gallery(rng, G: int):
    """(G, proto_dim) rows around G // N_PER_ID unit id-centers living in
    a rank-ID_RANK subspace; returns (rows, centers)."""
    U, _ = np.linalg.qr(rng.standard_normal((_CFG.proto_dim, ID_RANK)))
    z = _l2n(rng.standard_normal((G // N_PER_ID, ID_RANK))).astype(np.float32)
    centers = _l2n(z @ U.T.astype(np.float32))
    idx = np.repeat(np.arange(G // N_PER_ID), N_PER_ID)
    noise = _l2n(rng.standard_normal((G, _CFG.proto_dim))).astype(np.float32)
    return _l2n(centers[idx] + ID_RHO * noise).astype(np.float32), centers


def _mk_engine(C: int, G: int, mode: str, *, k: int, seed: int = 0,
               keep_fp32: bool = None):
    rng = np.random.default_rng(seed)
    protos, centers = [], []
    for _ in range(C):
        p, ctr = _clustered_gallery(rng, G)
        protos.append(p)
        centers.append(ctr)
    ids = [np.arange(G, dtype=np.int32) for _ in range(C)]
    index = GalleryIndex(protos, ids, nlist="auto",
                         keep_fp32=(mode == "fp32") if keep_fp32 is None
                         else keep_fp32)
    eng = RetrievalEngine(index, _stack_thetas(C, seed), k=k, mode=mode)
    return eng, centers, rng


def _mk_query(rng, centers_c):
    ctr = int(rng.integers(len(centers_c)))
    noise = _l2n(rng.standard_normal(_CFG.proto_dim)).astype(np.float32)
    return _l2n(centers_c[ctr] + ID_RHO * noise).astype(np.float32), ctr


def _mk_stream(rng, centers, n: int):
    """n (client, clustered proto, person qid) arrivals, uniform clients."""
    out = []
    for _ in range(n):
        c = int(rng.integers(len(centers)))
        q, ctr = _mk_query(rng, centers[c])
        out.append((c, q, ctr))
    return out


def _strip(r):
    return {k: v for k, v in r.items() if k != "tickets"}


def _measure_batched(engine, centers, rng, *, batch: int, n_queries: int,
                     with_open: bool = True):
    batcher = ContinuousBatcher(engine, batch=batch)
    batcher.submit(0, _mk_query(rng, centers[0])[0])
    batcher.drain()                                    # compile warmup
    closed = _strip(run_closed_loop(batcher, _mk_stream(rng, centers,
                                                        n_queries)))
    if not with_open:
        return {"closed": closed}
    rate = 0.6 * closed["qps"]
    open_ = _strip(run_open_loop(batcher,
                                 _mk_stream(rng, centers, n_queries // 2),
                                 rate))
    return {"closed": closed, "open": open_}


def _measure_naive(engine, centers, rng, *, n_queries: int):
    stream = _mk_stream(rng, centers, n_queries)
    engine.query_naive(stream[0][0], stream[0][1])     # compile warmup
    lat = []
    t0 = time.perf_counter()
    for client, proto, _ in stream:
        t1 = time.perf_counter()
        engine.query_naive(client, proto)
        lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    lat = np.array(lat)
    return {"n": n_queries, "wall_s": wall, "qps": n_queries / wall,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3)}


def _persons(ids):
    return np.where(ids >= 0, ids // N_PER_ID, -1)


def _ivf_fidelity(engv, eng8, centers, rng, *, k: int, n_eval: int = 128):
    """recall@k of the ivf shortlist vs the exact int8 path, plus the
    person-level mAP@k delta, over clustered queries with known ids."""
    C = len(centers)
    qp = np.zeros((C, n_eval, _CFG.proto_dim), np.float32)
    qids = np.zeros((C, n_eval), np.int64)
    for c in range(C):
        for b in range(n_eval):
            qp[c, b], qids[c, b] = _mk_query(rng, centers[c])
    qm = np.ones((C, n_eval), np.float32)
    i8, _ = eng8.query_batch(qp, qm, k=k)
    iv, _ = engv.query_batch(qp, qm, k=k)
    m8 = float(np.mean([map_from_ranked_ids(_persons(i8[c]), qids[c])
                        for c in range(C)]))
    mv = float(np.mean([map_from_ranked_ids(_persons(iv[c]), qids[c])
                        for c in range(C)]))
    return {"recall_at_k": recall_at_k(iv, i8, qm),
            "map_at_k_int8": m8, "map_at_k_ivf": mv,
            "map_delta_vs_int8": abs(m8 - mv)}


def _fidelity(C=5, n_tasks=2):
    """mAP over the synthetic ReID bench's eval galleries, full-gallery
    ranking per path; plus exact fp32-vs-host-oracle rank parity."""
    from benchmarks.eval_round import _setup
    _, strat, states, _, protos, cache = _setup(C, n_tasks)
    theta = strat.stack_eval_thetas(states)
    t = n_tasks - 1
    eng8 = RetrievalEngine.from_eval_cache(theta, cache, t, mode="int8",
                                           keep_fp32=True)
    engf = RetrievalEngine(eng8.index, theta, mode="fp32")
    G = eng8.index.capacity
    maps = {"int8": [], "fp32": []}
    parity = True
    for tt in range(t + 1):
        qp = np.stack([protos[(c, tt)][2] for c in range(C)])   # (C, Q, D)
        qids = np.stack([protos[(c, tt)][3] for c in range(C)])
        qmask = np.ones(qp.shape[:2], np.float32)
        ids8, _ = eng8.query_batch(qp, qmask, k=G)
        idsf, _ = engf.query_batch(qp, qmask, k=G)
        idsh, _ = engf.query_host(qp, qmask, k=G)
        parity = parity and bool(np.array_equal(idsf, idsh))
        for c in range(C):
            maps["int8"].append(map_from_ranked_ids(ids8[c], qids[c]))
            maps["fp32"].append(map_from_ranked_ids(idsf[c], qids[c]))
    m8 = float(np.mean(maps["int8"]))
    mf = float(np.mean(maps["fp32"]))
    return {"bench": f"synthetic C={C} T={n_tasks} (eval-cache galleries)",
            "gallery_rows": int(G), "map_fp32": mf, "map_int8": m8,
            "map_delta": abs(mf - m8), "tolerance": MAP_TOLERANCE,
            "within_tolerance": bool(abs(mf - m8) <= MAP_TOLERANCE),
            "fp32_rank_parity_vs_host_oracle": parity}


def bench_serve(Gs=(4096, 16384, G_FP32_MAX, G_INT8_MAX), *, C=4, batch=64,
                k=10, n_queries=512, n_naive=48, out=DEFAULT_OUT):
    cases = []
    print("G,int8_qps,ivf_qps,ivf_recall@k,fp32_qps,naive_qps,"
          "ivf_vs_int8,int8_vs_naive")
    for G in Gs:
        fits_fp32 = G <= G_FP32_MAX
        # one index serves every path; fp32 rows kept as the naive/exact
        # operand (beyond G_FP32_MAX that violates the declared budget —
        # flagged, kept only so the baseline exists to be beaten)
        eng8, centers, rng = _mk_engine(C, G, "int8", k=k, keep_fp32=True)
        index = eng8.index
        int8 = _measure_batched(eng8, centers, rng, batch=batch,
                                n_queries=n_queries)
        fp32 = None
        engf = RetrievalEngine(index, eng8.theta, k=k, mode="fp32",
                               refresh=False)
        if fits_fp32:
            fp32 = _measure_batched(engf, centers, rng, batch=batch,
                                    n_queries=n_queries)
        naive = _measure_naive(engf, centers, rng, n_queries=n_naive)

        # ---- ivf: nprobe sweep, recall/mAP vs the exact int8 oracle ----
        sweep = []
        for nprobe in NPROBE_SWEEP:
            engv = RetrievalEngine(index, eng8.theta, k=k, mode="ivf",
                                   nprobe=nprobe, refresh=False)
            fid = _ivf_fidelity(engv, eng8, centers, rng, k=k)
            perf = _measure_batched(
                engv, centers, rng, batch=batch, n_queries=n_queries,
                with_open=(nprobe == NPROBE_DEFAULT))
            sweep.append({
                "nprobe": nprobe,
                "rows_scored_per_query": int(nprobe * index.bcap),
                "rows_scored_frac": nprobe * index.bcap / G,
                **fid, **perf})
        default = next(s for s in sweep if s["nprobe"] == NPROBE_DEFAULT)
        case = {
            "G": int(G), "fits_fp32_budget": fits_fp32,
            "resident_bytes_int8": index.resident_bytes("int8"),
            "resident_bytes_fp32": index.resident_bytes("fp32"),
            "resident_bytes_ivf": index.resident_bytes("ivf"),
            "ivf_shape": {"nlist": index.nlist, "bcap": index.bcap,
                          "balance": index.ivf_balance,
                          "iters": index.ivf_iters,
                          "default_nprobe": NPROBE_DEFAULT},
            "int8": int8, "fp32": fp32, "naive_fp32": naive,
            "ivf_sweep": sweep,
            "speedup_vs_naive": int8["closed"]["qps"] / naive["qps"],
            "speedup_ivf_vs_int8": (default["closed"]["qps"]
                                    / int8["closed"]["qps"]),
            "ivf_recall_at_k": default["recall_at_k"],
        }
        cases.append(case)
        fqps = f"{fp32['closed']['qps']:.0f}" if fp32 else "-"
        print(f"{G},{int8['closed']['qps']:.0f},"
              f"{default['closed']['qps']:.0f},"
              f"{default['recall_at_k']:.3f},{fqps},{naive['qps']:.0f},"
              f"{case['speedup_ivf_vs_int8']:.1f}x,"
              f"{case['speedup_vs_naive']:.1f}x", flush=True)

    top = cases[-1]
    assert top["ivf_recall_at_k"] >= IVF_MIN_RECALL, \
        f"ivf recall@{k} {top['ivf_recall_at_k']:.3f} < {IVF_MIN_RECALL}"
    assert top["speedup_ivf_vs_int8"] >= IVF_MIN_SPEEDUP, \
        f"ivf speedup {top['speedup_ivf_vs_int8']:.2f}x < {IVF_MIN_SPEEDUP}x"

    fid = _fidelity()
    assert fid["fp32_rank_parity_vs_host_oracle"], \
        "serving fp32 path diverged from the numpy oracle"
    assert fid["within_tolerance"], \
        f"int8 mAP delta {fid['map_delta']:.4f} > {MAP_TOLERANCE}"
    print(f"fidelity: mAP fp32={fid['map_fp32']:.4f} "
          f"int8={fid['map_int8']:.4f} delta={fid['map_delta']:.4f} "
          f"(tol {MAP_TOLERANCE})")

    from benchmarks.common import mesh_metadata
    from repro.analysis.registry import coverage
    cov = coverage()
    payload = {
        "bench": "serve_round",
        "env": mesh_metadata(),
        "config": {"C": C, "batch": batch, "k": k, "n_queries": n_queries,
                   "n_naive": n_naive, "backend": jax.default_backend(),
                   "budget_bytes_per_client": BUDGET_BYTES,
                   "feat_dim": _CFG.feat_dim,
                   "gallery": {"n_per_id": N_PER_ID, "id_rank": ID_RANK,
                               "id_rho": ID_RHO},
                   "ivf_gate": {"min_recall_at_k": IVF_MIN_RECALL,
                                "min_speedup_vs_int8": IVF_MIN_SPEEDUP}},
        "capacity": {"fp32_rows_max": G_FP32_MAX,
                     "int8_rows_max": G_INT8_MAX,
                     "row_capacity_ratio": G_INT8_MAX / G_FP32_MAX},
        "analysis_coverage": {key: cov[key] for key in
                              ("programs_registered", "programs_traced")},
        "cases": cases,
        "fidelity": fid,
    }
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    return payload


def smoke():
    """Tiny end-to-end serve (run_tier1.sh --smoke hook): int8 + ivf +
    naive paths, fp32-vs-oracle parity, full-probe ivf recall == 1.0."""
    C, G = 3, 512
    eng8, centers, rng = _mk_engine(C, G, "int8", k=5, keep_fp32=True)
    int8 = _measure_batched(eng8, centers, rng, batch=16, n_queries=96)
    engv = RetrievalEngine(eng8.index, eng8.theta, k=5, mode="ivf",
                           nprobe=4, refresh=False)
    ivf = _measure_batched(engv, centers, rng, batch=16, n_queries=96,
                           with_open=False)
    fid = _ivf_fidelity(engv, eng8, centers, rng, k=5, n_eval=32)
    engall = RetrievalEngine(eng8.index, eng8.theta, k=5, mode="ivf",
                             nprobe=eng8.index.nlist, refresh=False)
    full = _ivf_fidelity(engall, eng8, centers, rng, k=5, n_eval=16)
    assert full["recall_at_k"] == 1.0, \
        f"full-probe ivf recall {full['recall_at_k']} != 1.0"
    engf = RetrievalEngine(eng8.index, eng8.theta, k=5, mode="fp32",
                           refresh=False)
    naive = _measure_naive(engf, centers, rng, n_queries=24)
    qp = rng.standard_normal((C, 4, _CFG.proto_dim)).astype(np.float32)
    qmask = np.ones((C, 4), np.float32)
    ids_d, _ = engf.query_batch(qp, qmask)
    ids_h, _ = engf.query_host(qp, qmask)
    assert np.array_equal(ids_d, ids_h), "fp32 serving != numpy oracle"
    print(f"serve smoke OK: G={G} int8 QPS={int8['closed']['qps']:.0f} "
          f"ivf QPS={ivf['closed']['qps']:.0f} "
          f"(nprobe=4 recall@5={fid['recall_at_k']:.3f}, full-probe "
          f"recall=1.0) naive QPS={naive['qps']:.0f}; fp32 ids == oracle")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny gallery end-to-end (wiring check, no JSON)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        bench_serve()


if __name__ == "__main__":
    main()
