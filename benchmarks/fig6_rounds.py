"""Paper Fig. 6/7: accuracy and forgetting over communication rounds for the
federated-lifelong methods."""
from __future__ import annotations

from benchmarks.common import csv_row, run

METHODS = ["fedavg", "fedcurv", "fedweit_b", "fedstil"]


def main():
    print("method,round,mAP,R1,forgetting_mAP")
    out = {}
    for m in METHODS:
        res, wall = run(m)
        out[m] = res.rounds
        for r in res.rounds:
            print(f"{m},{r['round']},{r['mAP']:.4f},{r['R1']:.4f},"
                  f"{r['forgetting_mAP']:.4f}", flush=True)
        csv_row(f"fig6/{m}", wall, f"final_mAP={res.final('mAP'):.4f}")
    return out


if __name__ == "__main__":
    main()
