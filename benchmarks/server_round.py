"""Per-round server wall time: host engine vs the stacked device program.

The host path is PR 1's parameter server — per-client upload dicts, tracker
push into host lists, (re)stacking C pytrees to a (C, P) matrix every round,
normalize on host, unstacking C base pytrees. The stacked path is this PR's
device-resident program — one batched ring push, decayed relevance over the
resident (C, k, D) history, and the fused normalize+mask+aggregate kernel
over the already-stacked (C, ...) parameter pytree.

``python -m benchmarks.run --bench server`` sweeps C ∈ {5, 20, 100} and
writes ``BENCH_server_round.json`` (repo root) so future PRs have a
machine-readable perf trajectory to regress against. The payload also
carries a ``telemetry`` block: the per-stage span breakdown of a traced
stacked round at the largest C, and the measured overhead of running
with tracing ON vs OFF — gated at <2% of stacked round wall-time, the
subsystem's off-by-default-cheap contract.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_size, tree_stack
from repro.core import edge_model as EM
from repro.core.edge_model import EdgeModelConfig
from repro.core.fedstil import FedSTIL
from repro.obs import trace as obs

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_server_round.json"

OVERHEAD_GATE = 0.02          # traced round may cost at most +2% wall-time


def _client_thetas(C: int, cfg: EdgeModelConfig):
    keys = jax.random.split(jax.random.PRNGKey(0), C)
    return [EM.init_adaptive_layers(k, cfg) for k in keys]


def _bench_host(C, cfg, thetas, feats, iters):
    strat = FedSTIL(cfg, n_clients=C)
    def one_round(r):
        uploads = {c: {"theta": thetas[c], "task_feature": feats[r % len(feats), c]}
                   for c in range(C)}
        dispatches = strat.server_round(r, uploads)
        jax.block_until_ready([jax.tree.leaves(d["B"])
                               for d in dispatches.values() if d])
    one_round(0)                             # warmup (jit compile)
    t0 = time.perf_counter()
    for r in range(1, iters + 1):
        one_round(r)
    return (time.perf_counter() - t0) / iters


def _bench_stacked(C, cfg, thetas, feats, iters):
    strat = FedSTIL(cfg, n_clients=C)
    stacked_theta = tree_stack(thetas)       # resident between rounds
    feats_dev = jnp.asarray(feats)
    def one_round(r):
        upload = {"theta": stacked_theta,
                  "task_feature": feats_dev[r % len(feats)]}
        d = strat.server_round_stacked(r, upload)
        jax.block_until_ready(jax.tree.leaves(d["B"]))
    one_round(0)                             # warmup (jit compile)
    t0 = time.perf_counter()
    for r in range(1, iters + 1):
        one_round(r)
    return (time.perf_counter() - t0) / iters


def measure_overhead(C=100, *, D=128, iters=8, repeats=3):
    """Measure the tracing tax on the stacked server round at client
    count C: the same resident-state round loop timed with the null
    tracer (``obs.suspended`` — the off-by-default path) and with a live
    ``obs.Tracer`` (stage spans, device syncs, metric readbacks).

    Min-of-``repeats`` on both sides so scheduler noise on a small CPU
    runner cannot fake an overhead. Returns (overhead dict incl. the
    <2% gate verdict, the tracer — its events carry the per-stage span
    breakdown the bench stamps into the payload)."""
    rng = np.random.default_rng(0)
    cfg = EdgeModelConfig()
    thetas = _client_thetas(C, cfg)
    feats = rng.standard_normal((iters + 1, C, D)).astype(np.float32)
    strat = FedSTIL(cfg, n_clients=C)
    stacked_theta = tree_stack(thetas)
    feats_dev = jnp.asarray(feats)

    def one_round(r):
        upload = {"theta": stacked_theta,
                  "task_feature": feats_dev[r % (iters + 1)]}
        d = strat.server_round_stacked(r, upload)
        jax.block_until_ready(jax.tree.leaves(d["B"]))

    one_round(0)                             # warmup (jit compile)

    def timed():
        t0 = time.perf_counter()
        for r in range(1, iters + 1):
            one_round(r)
        return (time.perf_counter() - t0) / iters

    tracer = obs.Tracer()
    off, on = [], []
    for _ in range(repeats):
        with obs.suspended():
            off.append(timed())
        with obs.active(tracer):
            on.append(timed())
    base, traced = min(off), min(on)
    frac = max(0.0, traced - base) / base
    return ({"C": C, "iters": iters, "repeats": repeats,
             "untraced_ms": base * 1e3, "traced_ms": traced * 1e3,
             "overhead_frac": frac, "gate": OVERHEAD_GATE,
             "pass": bool(frac < OVERHEAD_GATE)}, tracer)


def bench_server_round(Cs=(5, 20, 100), *, D=128, iters=8, out=DEFAULT_OUT):
    rng = np.random.default_rng(0)
    cfg = EdgeModelConfig()
    cases = []
    print("C,host_ms,stacked_ms,speedup")
    for C in Cs:
        thetas = _client_thetas(C, cfg)
        feats = rng.standard_normal((iters + 1, C, D)).astype(np.float32)
        host_s = _bench_host(C, cfg, thetas, feats, iters)
        stacked_s = _bench_stacked(C, cfg, thetas, feats, iters)
        case = {"C": C, "host_ms": host_s * 1e3,
                "stacked_ms": stacked_s * 1e3,
                "speedup": host_s / stacked_s}
        cases.append(case)
        print(f"{C},{case['host_ms']:.2f},{case['stacked_ms']:.2f},"
              f"{case['speedup']:.1f}x", flush=True)
    # telemetry: trace the stacked round at the largest C, stamp the
    # per-stage span breakdown + the measured on-vs-off overhead gate
    overhead, tracer = measure_overhead(C=max(Cs), D=D, iters=iters)
    from repro.obs.report import telemetry_block
    telemetry = telemetry_block(tracer.events)
    telemetry["overhead"] = overhead
    print(f"tracing overhead @C={overhead['C']}: "
          f"{overhead['untraced_ms']:.2f}ms -> {overhead['traced_ms']:.2f}ms "
          f"({overhead['overhead_frac'] * 100:.2f}%, gate "
          f"{overhead['gate'] * 100:.0f}%: "
          f"{'PASS' if overhead['pass'] else 'FAIL'})", flush=True)
    from benchmarks.common import mesh_metadata
    from repro.analysis.registry import coverage
    cov = coverage()
    payload = {
        "bench": "server_round",
        "env": mesh_metadata(),
        "config": {"D": D, "history_len": 6, "iters": iters,
                   "params_per_client": tree_size(thetas[0]),
                   "backend": jax.default_backend()},
        "analysis_coverage": {k: cov[k] for k in ("programs_registered",
                                                  "programs_traced")},
        "cases": cases,
        "telemetry": telemetry,
    }
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    return payload


def main():
    bench_server_round()


if __name__ == "__main__":
    main()
