"""Paper Fig. 8: accuracy vs cumulative communication cost."""
from __future__ import annotations

from benchmarks.common import csv_row, run
from repro.comm.accounting import fmt_bytes

METHODS = ["fedavg", "fedprox", "fedcurv", "fedweit_a", "fedweit_b", "fedstil"]


def main():
    print("method,total_comm_bytes,total_comm,final_mAP")
    out = {}
    for m in METHODS:
        res, wall = run(m)
        out[m] = (res.comm.total, res.final("mAP"))
        print(f"{m},{res.comm.total},{fmt_bytes(res.comm.total)},"
              f"{res.final('mAP'):.4f}", flush=True)
        csv_row(f"fig8/{m}", wall, f"bytes={res.comm.total}")
    return out


if __name__ == "__main__":
    main()
