"""Paper Fig. 8: accuracy vs cumulative communication cost.

Reports, per method: total wire bytes (MEASURED encoded-buffer sizes when
the method carries a wire codec, the analytic formula otherwise), the
always-recorded formula bytes as the cross-check oracle, the comm
reduction vs the dense FedAvg baseline, and final mAP. ``fedstil_wire`` is
FedSTIL with the default ``topk+int8`` wire codec — the measured artifact
behind the paper's ~62% comm-reduction claim.
"""
from __future__ import annotations

from benchmarks.common import csv_row, run
from repro.comm.accounting import fmt_bytes

METHODS = ["fedavg", "fedprox", "fedcurv", "fedweit_a", "fedweit_b",
           "fedstil", "fedstil_wire"]


def main():
    print("method,wire_bytes,wire,formula_bytes,reduction_vs_fedavg,final_mAP")
    out = {}
    baseline = None
    for m in METHODS:
        if m == "fedstil_wire":
            res, wall = run("fedstil", codec="topk+int8")
        else:
            res, wall = run(m)
        if m == "fedavg":
            baseline = res.comm.total
        red = (1.0 - res.comm.total / baseline) if baseline else 0.0
        out[m] = (res.comm.total, res.final("mAP"))
        print(f"{m},{res.comm.total},{fmt_bytes(res.comm.total)},"
              f"{res.comm.total_formula},{red * 100:.1f}%,"
              f"{res.final('mAP'):.4f}", flush=True)
        csv_row(f"fig8/{m}", wall,
                f"bytes={res.comm.total};reduction={red * 100:.1f}%")
    return out


if __name__ == "__main__":
    main()
