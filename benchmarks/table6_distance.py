"""Paper Table VI: task-similarity distance metric (KL vs Cosine vs
Euclidean) for the spatial-temporal integration."""
from __future__ import annotations

from benchmarks.common import csv_row, run

METRICS = ["cosine", "euclidean", "kl"]


def main():
    print("distance,mAP,R1,R3,R5")
    out = {}
    for metric in METRICS:
        res, wall = run("fedstil", metric=metric)
        f = res.final_metrics()
        out[metric] = f
        print(f"{metric},{f['mAP']:.4f},{f['R1']:.4f},{f['R3']:.4f},"
              f"{f['R5']:.4f}", flush=True)
        csv_row(f"table6/{metric}", wall, f"mAP={f['mAP']:.4f}")
    return out


if __name__ == "__main__":
    main()
