"""Mesh-resident server round scaling: stacked (1-device) vs sharded
(forced 8-device host mesh) FedSTIL server rounds at C → 10k clients.

Both paths run the SAME staged device programs (`FedSTIL.server_round_stacked`:
ring push + Eq. 4/5 relevance, (C, P) flatten, fused Eq. 5→6 aggregate);
the sharded path additionally pads C to the data-axis multiple, places the
(Cp, P) payload client-row-sharded (`sharding.specs`), ships the flatten
in bf16 wire form, and pins the aggregate output row-sharded (a
reduce-scatter: each device ends the round holding Cp/d × P bases, never
the full C × P). On this host the 8 "devices" are threads multiplexed
onto one physical core, so the sharded path pays a constant collective +
scheduling overhead (measured ratio 2-4x vs stacked) and no speedup is
expected; what this bench pins down is (1) the sharded path completes a
C=10k round at all, (2) its per-device peak bytes scale as Cp/d x P, and
(3) the ratio stays a flat constant (a regression in the SPMD lowering
shows up as a ratio blow-up with C).

Scaling dims are synthetic (P=1024, D=16, k=2 — recorded in config): C is
the swept axis, and the paper model's real payload is covered by
``--bench server``.

``python -m benchmarks.run --bench mesh`` sweeps C ∈ {100, 1000, 10000}
and writes ``BENCH_mesh_round.json``; ``--smoke`` runs C=100 only and
asserts sharded-vs-stacked parity on the aggregated bases.
"""
from __future__ import annotations

import os

# must precede the jax import: the forced 8-device host platform is the
# whole point of this bench
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_mesh_round.json"
P_DIM = 1024
HIST, D = 2, 16


def _per_device_peak(strat, lead: int):
    """Per-device peak bytes of the sharded aggregate program: XLA's
    ``memory_analysis`` when the backend exposes it, else the analytic
    layout footprint (resident shards + the f32 upcast + outputs)."""
    agg = strat._jit_cache.get("sharded_aggregate")
    mesh = strat.mesh
    d = mesh.shape["data"]
    wire = jnp.bfloat16 if strat.wire_dtype == "bfloat16" else jnp.float32
    if agg is not None:
        try:
            args = (jax.ShapeDtypeStruct((lead, lead), jnp.float32),
                    jax.ShapeDtypeStruct((lead, P_DIM), wire))
            mem = agg.lower(*args).compile().memory_analysis()
            total = (int(mem.temp_size_in_bytes)
                     + int(mem.argument_size_in_bytes)
                     + int(mem.output_size_in_bytes))
            if total > 0:
                return {"source": "xla", "bytes": total // d}
        except Exception:
            pass
    itemsize = jnp.dtype(wire).itemsize
    per_dev = (lead // d) * P_DIM * (itemsize + 4 + 4)  # wire + upcast + B
    per_dev += lead * lead * 4 * 2                      # W in + Wn out (repl.)
    return {"source": "analytic", "bytes": int(per_dev)}


def _one_engine(C: int, iters: int, *, sharded: bool):
    from repro.core.edge_model import EdgeModelConfig
    from repro.core.fedstil import FedSTIL
    from repro.federated.base import pad_client_rows
    from repro.sharding import specs as shard_specs

    strat = FedSTIL(EdgeModelConfig(), n_clients=C, history_len=HIST)
    rng = np.random.default_rng(0)
    theta = {"w": jnp.asarray(rng.standard_normal((C, P_DIM)), jnp.float32)}
    feats = rng.standard_normal((iters + 1, C, D)).astype(np.float32)
    valid, lead = None, C
    if sharded:
        mesh = shard_specs.engine_mesh()
        strat.mesh = mesh
        lead = shard_specs.padded_clients(C, mesh)
        theta = pad_client_rows(theta, lead)
        theta = jax.device_put(theta, shard_specs.named_shardings(
            mesh, shard_specs.stacked_tree_specs(theta)))
        valid = jnp.concatenate([jnp.ones((C,), jnp.float32),
                                 jnp.zeros((lead - C,), jnp.float32)])
        valid = jax.device_put(valid, jax.sharding.NamedSharding(
            mesh, shard_specs.client_row_spec(1)))

    last = {}

    def one_round(r):
        f = feats[r % feats.shape[0]]
        if lead > C:
            f = np.concatenate([f, np.zeros((lead - C, D), np.float32)])
        upload = {"theta": theta, "task_feature": jnp.asarray(f)}
        d = strat.server_round_stacked(r, upload, valid=valid)
        jax.block_until_ready(jax.tree.leaves(d["B"]))
        last["B"] = d["B"]["w"]

    one_round(0)                             # warmup (jit compile)
    t0 = time.perf_counter()
    for r in range(1, iters + 1):
        one_round(r)
    per_round = (time.perf_counter() - t0) / iters
    peak = _per_device_peak(strat, lead) if sharded else None
    return per_round, lead, peak, np.asarray(last["B"][:C])


def bench_mesh_round(Cs=(100, 1000, 10000), *, out=DEFAULT_OUT, smoke=False):
    if smoke:
        Cs = (100,)
    mesh_d = None
    cases = []
    print(f"payload P={P_DIM}, D={D}, history k={HIST}, "
          f"devices={jax.device_count()}")
    print("C,Cp,stacked_ms,sharded_ms,ratio,per_device_peak")
    for C in Cs:
        iters = 1 if C >= 10000 else 3
        stacked_s, _, _, b_st = _one_engine(C, iters, sharded=False)
        sharded_s, Cp, peak, b_sh = _one_engine(C, iters, sharded=True)
        if smoke:
            # bf16 wire is the only delta between the two paths
            np.testing.assert_allclose(b_sh, b_st, atol=5e-2, rtol=5e-2)
            print(f"parity OK: sharded B[:{C}] == stacked B (bf16 wire tol)")
        case = {"C": C, "Cp": Cp, "iters": iters,
                "stacked_ms": stacked_s * 1e3,
                "sharded_ms": sharded_s * 1e3,
                "ratio": sharded_s / stacked_s,
                "per_device_peak": peak}
        cases.append(case)
        mesh_d = peak and peak.get("source")
        print(f"{C},{Cp},{case['stacked_ms']:.2f},{case['sharded_ms']:.2f},"
              f"{case['ratio']:.2f}x,{peak['bytes']}", flush=True)
    from benchmarks.common import mesh_metadata
    from repro.analysis.registry import coverage
    cov = coverage()
    payload = {
        "bench": "mesh_round",
        "env": mesh_metadata(),
        "config": {"P": P_DIM, "D": D, "history_len": HIST,
                   "wire_dtype": "bfloat16",
                   "peak_source": mesh_d,
                   "backend": jax.default_backend()},
        "analysis_coverage": {k: cov[k] for k in ("programs_registered",
                                                  "programs_traced")},
        "cases": cases,
    }
    if not smoke:
        Path(out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="C=100 only + sharded-vs-stacked parity assert")
    args = ap.parse_args()
    bench_mesh_round(smoke=args.smoke)


if __name__ == "__main__":
    main()
