"""Paper Table II: methods x (mAP, R1, R3, R5, storage, S2C, C2S).

Validates the paper's ordering claims on the synthetic mixture:
federated-lifelong (FedSTIL) > federated > lifelong/local on accuracy,
with FedSTIL's comm cost ~= FedAvg's and << FedCurv/FedWeIT's.
"""
from __future__ import annotations

from benchmarks.common import csv_row, run
from repro.comm.accounting import fmt_bytes

METHODS = ["stl", "ewc", "mas", "icarl", "fedavg", "fedprox",
           "fedcurv", "fedweit_a", "fedweit_b", "fedstil"]


def main(methods=METHODS, rounds=None):
    print("method,mAP,R1,R3,R5,storage,S2C,C2S")
    results = {}
    for m in methods:
        kw = {"rounds": rounds} if rounds else {}
        res, wall = run(m, **kw)
        f = res.final_metrics()
        results[m] = res
        print(f"{m},{f['mAP']:.4f},{f['R1']:.4f},{f['R3']:.4f},{f['R5']:.4f},"
              f"{fmt_bytes(res.storage_bytes)},{fmt_bytes(res.comm.total_s2c)},"
              f"{fmt_bytes(res.comm.total_c2s)}", flush=True)
        csv_row(f"table2/{m}", wall, f"mAP={f['mAP']:.4f}")
    return results


if __name__ == "__main__":
    main()
