"""Per-round wire-codec wall time: host per-client encode loop vs the
batched device program.

The host path is what a real parameter server would do naively: encode and
decode each of C clients' payloads one at a time with the numpy
``PipelineCodec``. The batched path is the stacked engine's
``comm.BatchedCodec``: ALL C clients' flattened (C, P) payload rows go
through one jitted sparsify+quantize program (``kernels/topk_pack.py`` +
``kernels/quantize.py`` via ``kernels.ops``), encoded buffers staying on
device.

``python -m benchmarks.run --bench comm`` sweeps C ∈ {5, 20, 100} at the
edge model's real payload size and writes ``BENCH_comm_round.json`` (repo
root). ``--smoke`` runs C=5 only and additionally asserts host-vs-batched
parity: identical wire bytes and matching reconstructions (the tier-1
smoke in ``scripts/run_tier1.sh --smoke``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.comm.batched import BatchedCodec
from repro.comm.codec import make_codec
from repro.common.pytree import tree_size
from repro.core import edge_model as EM
from repro.core.edge_model import EdgeModelConfig

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_comm_round.json"
SPEC = "topk+int8"


def _payload_dim() -> int:
    cfg = EdgeModelConfig()
    theta = EM.init_adaptive_layers(jax.random.PRNGKey(0), cfg)
    return tree_size(theta)


def _bench_host(mat: np.ndarray, iters: int) -> float:
    C = mat.shape[0]
    codec = make_codec(SPEC, delta=False)
    def one_round():
        for c in range(C):
            payload = codec.encode({"theta": mat[c]})
            codec.decode(payload)
    one_round()                              # warm caches
    t0 = time.perf_counter()
    for _ in range(iters):
        one_round()
    return (time.perf_counter() - t0) / iters


def _bench_batched(mat: np.ndarray, iters: int):
    import jax.numpy as jnp
    codec = BatchedCodec(make_codec(SPEC, delta=False), mat.shape[1])
    dev = jnp.asarray(mat)
    wire = codec.per_client_bytes(codec.encode(dev))
    def one_round():
        buffers = codec.encode(dev)
        jax.block_until_ready(codec.decode(buffers))
    one_round()                              # warmup (jit compile)
    t0 = time.perf_counter()
    for _ in range(iters):
        one_round()
    return (time.perf_counter() - t0) / iters, wire


def _parity_check(mat: np.ndarray) -> None:
    """Host codec and batched device program must produce the same wire
    bytes and the same reconstruction (delta off: single-shot parity)."""
    host = make_codec(SPEC, delta=False)
    batched = BatchedCodec(make_codec(SPEC, delta=False), mat.shape[1])
    buffers = batched.encode(np.asarray(mat))
    dec_b = np.asarray(batched.decode(buffers))
    per_client_b = batched.per_client_bytes(buffers)
    for c in range(mat.shape[0]):
        payload = host.encode({"theta": mat[c]})
        assert payload.nbytes == per_client_b, \
            (payload.nbytes, per_client_b)
        dec_h = host.decode(payload)["theta"]
        np.testing.assert_allclose(dec_h, dec_b[c], atol=1e-6, rtol=0)
    print(f"parity OK: per-client wire bytes={per_client_b}, "
          f"decoded host==batched for C={mat.shape[0]}")


def bench_comm_round(Cs=(5, 20, 100), *, iters=5, out=DEFAULT_OUT,
                     smoke=False):
    P = _payload_dim()
    rng = np.random.default_rng(0)
    if smoke:
        Cs, iters = (5,), 2
    cases = []
    print(f"payload P={P} ({P * 4} dense bytes/client), codec={SPEC}")
    print("C,host_ms,batched_ms,speedup,wire_bytes_per_client,reduction")
    for C in Cs:
        mat = rng.standard_normal((C, P)).astype(np.float32)
        if smoke:
            _parity_check(mat)
        host_s = _bench_host(mat, iters)
        batched_s, wire = _bench_batched(mat, iters)
        case = {"C": C, "host_ms": host_s * 1e3,
                "batched_ms": batched_s * 1e3,
                "speedup": host_s / batched_s,
                "wire_bytes_per_client": wire,
                "dense_bytes_per_client": P * 4,
                "reduction": 1.0 - wire / (P * 4)}
        cases.append(case)
        print(f"{C},{case['host_ms']:.2f},{case['batched_ms']:.2f},"
              f"{case['speedup']:.1f}x,{wire},{case['reduction']:.3f}",
              flush=True)
    from benchmarks.common import mesh_metadata
    from repro.analysis.registry import coverage
    cov = coverage()
    payload = {
        "bench": "comm_round",
        "env": mesh_metadata(),
        "config": {"P": P, "codec": SPEC, "iters": iters,
                   "backend": jax.default_backend()},
        "analysis_coverage": {k: cov[k] for k in ("programs_registered",
                                                  "programs_traced")},
        "cases": cases,
    }
    if not smoke:
        Path(out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="C=5 only + host-vs-batched parity assert")
    args = ap.parse_args()
    bench_comm_round(smoke=args.smoke)


if __name__ == "__main__":
    main()
