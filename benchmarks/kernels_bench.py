"""Kernel micro-benchmarks: jnp-oracle wall time on CPU (the Pallas path is
TPU-targeted; interpret mode is correctness-only) + analytic TPU roofline
estimates per kernel (bytes moved / FLOPs / v5e bounds)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.sharding.analysis import HBM_BW, PEAK_FLOPS_BF16


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def main():
    key = jax.random.PRNGKey(0)
    print("kernel,us_per_call,analytic_tpu_bound")

    # flash attention (B,H,S,hd)
    q = jax.random.normal(key, (1, 4, 512, 64), jnp.float32)
    t = _time(lambda a: ops.flash_attention(a, q, q), q)
    flops = 4 * 1 * 4 * 512 * 512 * 64
    print(f"flash_attention_512,{t*1e6:.0f},"
          f"tpu_compute_bound={flops/PEAK_FLOPS_BF16*1e6:.2f}us", flush=True)

    # pairwise dist (2k x 4k gallery, D=128)
    qf = jax.random.normal(key, (2048, 128))
    gf = jax.random.normal(key, (4096, 128))
    t = _time(lambda a, b: ops.pairwise_dist(a, b), qf, gf)
    flops = 2 * 2048 * 4096 * 128
    print(f"pairwise_dist_2kx4k,{t*1e6:.0f},"
          f"tpu_compute_bound={flops/PEAK_FLOPS_BF16*1e6:.2f}us", flush=True)

    # adaptive combine (1M params)
    b = jax.random.normal(key, (1_000_000,))
    t = _time(lambda x: ops.adaptive_combine(x, x, x), b)
    bytes_ = 4 * 4 * 1_000_000
    print(f"adaptive_combine_1M,{t*1e6:.0f},"
          f"tpu_mem_bound={bytes_/HBM_BW*1e6:.2f}us", flush=True)

    # relevance aggregate (5 clients x 1M params)
    th = jax.random.normal(key, (5, 1_000_000))
    w = jax.nn.softmax(jax.random.normal(key, (5, 5)))
    t = _time(lambda a, x: ops.relevance_aggregate(a, x), w, th)
    bytes_ = 4 * 2 * 5 * 1_000_000
    print(f"relevance_aggregate_5x1M,{t*1e6:.0f},"
          f"tpu_mem_bound={bytes_/HBM_BW*1e6:.2f}us", flush=True)

    # kl similarity (history 30 x 30, D=128)
    a = jax.random.normal(key, (30, 128))
    t = _time(lambda x: ops.kl_similarity(x, x), a)
    print(f"kl_similarity_30x30,{t*1e6:.0f},negligible", flush=True)


if __name__ == "__main__":
    main()
