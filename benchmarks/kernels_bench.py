"""Kernel micro-benchmarks: jnp-oracle wall time on CPU (the Pallas path is
TPU-targeted; interpret mode is correctness-only) + analytic TPU roofline
estimates per kernel (bytes moved / FLOPs / v5e bounds), plus the
server-round `relevance` sweep (batched Eq. 4/5 vs the O(C²·k) Python
loop reference)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.relevance import RelevanceTracker
from repro.kernels import ops
from repro.sharding.analysis import HBM_BW, PEAK_FLOPS_BF16


def _time(fn, *args, iters=5):
    # warmup: evaluate exactly once (a second call here would double-count
    # one-shot compile/dispatch cost into the warmup of cheap kernels)
    out = fn(*args)
    out[0].block_until_ready() if isinstance(out, tuple) else \
        jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def _wall(fn, iters=1, warmup=True):
    if warmup:
        fn()
    t0 = time.time()
    for _ in range(iters):
        fn()
    return (time.time() - t0) / iters


def bench_relevance(Cs=(5, 20, 100), ks=(6, 24), D=128):
    """Decayed all-pairs relevance (Eq. 4/5) on the parameter server: the
    batched (C, C·k) path vs the loop reference (one device round-trip per
    (i, j, age) similarity — the pre-vectorization scaling bottleneck)."""
    rng = np.random.default_rng(0)
    print("case,loop_ms,batched_ms,speedup")
    for C in Cs:
        for k in ks:
            tr = RelevanceTracker(C, history_len=k, metric="kl")
            for _ in range(k):
                for c in range(C):
                    tr.push(c, rng.standard_normal(D).astype(np.float32))
            t_bat = _wall(lambda: tr.relevance(), iters=5)
            # the loop's cost IS the dispatch overhead: a single call,
            # no warmup (there is nothing to compile)
            t_loop = _wall(lambda: tr.relevance(backend="loop"),
                           iters=1, warmup=False)
            print(f"relevance_C{C}_k{k},{t_loop*1e3:.1f},{t_bat*1e3:.2f},"
                  f"{t_loop/t_bat:.0f}x", flush=True)


def main():
    key = jax.random.PRNGKey(0)
    print("kernel,us_per_call,analytic_tpu_bound")

    # flash attention (B,H,S,hd)
    q = jax.random.normal(key, (1, 4, 512, 64), jnp.float32)
    t = _time(lambda a: ops.flash_attention(a, q, q), q)
    flops = 4 * 1 * 4 * 512 * 512 * 64
    print(f"flash_attention_512,{t*1e6:.0f},"
          f"tpu_compute_bound={flops/PEAK_FLOPS_BF16*1e6:.2f}us", flush=True)

    # pairwise dist (2k x 4k gallery, D=128)
    qf = jax.random.normal(key, (2048, 128))
    gf = jax.random.normal(key, (4096, 128))
    t = _time(lambda a, b: ops.pairwise_dist(a, b), qf, gf)
    flops = 2 * 2048 * 4096 * 128
    print(f"pairwise_dist_2kx4k,{t*1e6:.0f},"
          f"tpu_compute_bound={flops/PEAK_FLOPS_BF16*1e6:.2f}us", flush=True)

    # adaptive combine (1M params)
    b = jax.random.normal(key, (1_000_000,))
    t = _time(lambda x: ops.adaptive_combine(x, x, x), b)
    bytes_ = 4 * 4 * 1_000_000
    print(f"adaptive_combine_1M,{t*1e6:.0f},"
          f"tpu_mem_bound={bytes_/HBM_BW*1e6:.2f}us", flush=True)

    # relevance aggregate (5 clients x 1M params)
    th = jax.random.normal(key, (5, 1_000_000))
    w = jax.nn.softmax(jax.random.normal(key, (5, 5)))
    t = _time(lambda a, x: ops.relevance_aggregate(a, x), w, th)
    bytes_ = 4 * 2 * 5 * 1_000_000
    print(f"relevance_aggregate_5x1M,{t*1e6:.0f},"
          f"tpu_mem_bound={bytes_/HBM_BW*1e6:.2f}us", flush=True)

    # kl similarity (history 30 x 30, D=128)
    a = jax.random.normal(key, (30, 128))
    t = _time(lambda x: ops.kl_similarity(x, x), a)
    print(f"kl_similarity_30x30,{t*1e6:.0f},negligible", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=["all", "kernels", "relevance"],
                    default="all")
    args = ap.parse_args()
    if args.only in ("all", "kernels"):
        main()
    if args.only in ("all", "relevance"):
        bench_relevance()
