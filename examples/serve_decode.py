"""Example 4: serving — batched greedy decoding through the KV-cache /
SSM-state path for three different architecture families, including the
sliding-window ring cache (the long_500k mechanism) and an SSM whose state
is O(1) in context length.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import subprocess
import sys

for argv in (
    ["--arch", "qwen1.5-0.5b", "--batch", "2", "--prompt-len", "8", "--gen", "24"],
    ["--arch", "qwen1.5-0.5b", "--batch", "2", "--prompt-len", "8", "--gen", "24",
     "--window", "16"],                       # ring cache (long-context mode)
    ["--arch", "rwkv6-1.6b", "--batch", "2", "--prompt-len", "8", "--gen", "24"],
    ["--arch", "zamba2-2.7b", "--batch", "2", "--prompt-len", "8", "--gen", "24"],
):
    print("\n$ python -m repro.launch.serve_lm", " ".join(argv), flush=True)
    subprocess.run([sys.executable, "-m", "repro.launch.serve_lm"] + argv,
                   check=True)
