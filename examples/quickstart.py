"""Quickstart: FedSTIL in ~40 lines.

Five edge clients, six sequential tasks of drifting synthetic ReID data,
spatial-temporal knowledge integration on the server — prints per-round
accuracy and the final relevance matrix W (Eq. 5).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.federated import run_simulation

# 1. The federated lifelong benchmark (synthetic stand-in for the paper's
#    five-dataset mixture; see DESIGN.md §1).
bench = FederatedReIDBenchmark(n_clients=5, n_tasks=6, n_identities=120,
                               ids_per_task=12, samples_per_id=8, seed=0)

# 2. The edge model: frozen extraction layers + FedSTIL-decomposed adaptive
#    layers (theta = B ⊙ alpha + A, Eq. 2).
cfg = EdgeModelConfig(n_classes=bench.n_classes)

# 3. The paper's method.
strategy = FedSTIL(cfg, n_clients=5, metric="kl", forgetting_ratio=0.5,
                   memory_size=1000, epochs=4)

# 4. Run the federated lifelong simulation.
res = run_simulation(strategy, bench, rounds=12, eval_every=3, verbose=True)

print(f"\nfinal mAP={res.final('mAP'):.4f}  R1={res.final('R1'):.4f}  "
      f"forgetting={res.rounds[-1]['forgetting_mAP']:.4f}")
print(f"comm: C2S={res.comm.total_c2s/1e6:.1f}MB "
      f"S2C={res.comm.total_s2c/1e6:.1f}MB  storage={res.storage_bytes/1e6:.1f}MB")
print("\nknowledge relevance W (rows=receiving client, Eq. 5):")
print(np.round(strategy.last_W, 3))
