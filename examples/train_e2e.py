"""Example 3: end-to-end training driver — a ~100M-parameter qwen-family
model (FULL qwen1.5-0.5b trunk reduced to ~100M by layer count) trained for
a few hundred steps on structured synthetic tokens with the FedSTIL split
(frozen extraction trunk, adaptive last block + head, theta = B⊙alpha+A).

Loss must drop substantially; prints a CSV learning curve and saves a
checkpoint.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data.tokens import synthetic_lm_batch
from repro.train import init_train_state, make_train_step
from repro.train.optimizer import adam, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: qwen1.5-0.5b arch, 8 layers, d=768, vocab 32k
    cfg = dataclasses.replace(
        get_config("qwen1.5-0.5b"),
        name="qwen-100m", n_layers=8, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=2048, vocab_size=32000, head_dim=0,
        param_dtype="float32", compute_dtype="float32", fsdp=False,
        n_adaptive_layers=2)
    n_params = cfg.n_params()
    print(f"model: {cfg.name}  ~{n_params/1e6:.0f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    opt = adam(lr=1e-3, weight_decay=1e-5,
               schedule=cosine_schedule(warmup=20, total=args.steps))
    st = init_train_state(cfg, jax.random.PRNGKey(0), optimizer=opt)
    step = jax.jit(make_train_step(cfg, optimizer=opt, tie_lambda=1e-4))

    rng = np.random.default_rng(0)
    trainable, opt_state = st.trainable, st.opt_state
    t0 = time.time()
    print("step,loss,tokens_per_s")
    first = last = None
    for i in range(args.steps):
        toks, labels = synthetic_lm_batch(rng, args.batch, args.seq, cfg.vocab_size)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        trainable, opt_state, m = step(st.frozen, st.B, trainable, opt_state,
                                       batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 20 == 0 or i == args.steps - 1:
            tps = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"{i},{loss:.4f},{tps:.0f}", flush=True)

    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first - 0.5 else 'WARN'})")
    save_checkpoint("results/e2e_qwen100m.npz",
                    {"trainable": trainable},
                    metadata={"arch": cfg.name, "steps": args.steps,
                              "final_loss": last})
    print("checkpoint -> results/e2e_qwen100m.npz")


if __name__ == "__main__":
    main()
