"""Example 2: method comparison — FedSTIL vs FedAvg vs STL vs EWC on the
same drifting federated ReID streams, with communication accounting
(a miniature of paper Table II / Fig. 8).

Run:  PYTHONPATH=src python examples/federated_lifelong_reid.py
"""
from repro.comm.accounting import fmt_bytes
from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.federated import FedAvg, run_simulation
from repro.lifelong import EWC, STL

bench = FederatedReIDBenchmark(n_clients=5, n_tasks=6, n_identities=120,
                               ids_per_task=12, samples_per_id=8, seed=0)
cfg = EdgeModelConfig(n_classes=bench.n_classes)

strategies = [
    STL(cfg, epochs=3),
    EWC(cfg, epochs=3),
    FedAvg(cfg, epochs=3),
    FedSTIL(cfg, n_clients=5, epochs=3),
]

print(f"{'method':10s} {'mAP':>7s} {'R1':>7s} {'forget':>7s} "
      f"{'comm':>9s} {'storage':>9s}")
for s in strategies:
    res = run_simulation(s, bench, rounds=12, eval_every=4)
    f = res.final_metrics()
    print(f"{s.name:10s} {f['mAP']:7.4f} {f['R1']:7.4f} "
          f"{f['forgetting_mAP']:7.4f} {fmt_bytes(res.comm.total):>9s} "
          f"{fmt_bytes(res.storage_bytes):>9s}")
