"""§Perf before/after: analytic roofline terms + HLO-parsed evidence for the
three hillclimbed (arch x shape) pairs."""
import json, sys
sys.path.insert(0, "src")
from repro.configs import INPUT_SHAPES, get_config
from repro.sharding.analysis import HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS_BF16
from repro.sharding.analytic import analytic_roofline

def terms(an):
    t = {"compute": an["flops_per_device"]/PEAK_FLOPS_BF16,
         "memory": an["hbm_bytes_per_device"]/HBM_BW,
         "collective": an["collective_bytes_per_device"]/(ICI_BW*ICI_LINKS)}
    t["bottleneck"] = max(t, key=lambda k: t[k] if k != "bottleneck" else -1)
    t["total_bound"] = max(v for k, v in t.items() if k != "bottleneck")
    return t

def hlo(path):
    r = json.load(open(path))
    return {"hlo_coll": r["collectives"]["total_bytes"],
            "hlo_flops": r["cost"].get("flops"),
            "hlo_counts": r["collectives"]["count"],
            "compile_s": r["compile_s"]}

rows = []

# 1. qwen1.5-0.5b train_4k: TP layout -> DP layout over the model axis
cfg = get_config("qwen1.5-0.5b"); sh = INPUT_SHAPES["train_4k"]
base = terms(analytic_roofline(cfg, sh, tp=16, dp=16))
opt  = terms(analytic_roofline(cfg, sh, tp=1, dp=256))
rows.append(("qwen1.5-0.5b/train_4k", "TP16 -> model-axis-DP (params replicated)",
             base, opt,
             hlo("results/dryrun/qwen1.5-0.5b__train_4k__sp.json"),
             hlo("results/hillclimb/qwen_dp/qwen1.5-0.5b__train_4k__sp.json")))

# 2. llama3-405b decode_32k: FSDP gather -> weight-stationary
cfg = get_config("llama3-405b"); sh = INPUT_SHAPES["decode_32k"]
base = terms(analytic_roofline(cfg, sh))
opt  = terms(analytic_roofline(cfg, sh, decode_ws=True))
rows.append(("llama3-405b/decode_32k", "per-token FSDP weight gathers -> weight-stationary (activations move)",
             base, opt,
             hlo("results/dryrun/llama3-405b__decode_32k__sp.json"),
             hlo("results/hillclimb/llama_ws/llama3-405b__decode_32k__sp.json")))

# 2b. arctic decode (same optimization generalizes)
cfg = get_config("arctic-480b"); sh = INPUT_SHAPES["decode_32k"]
base = terms(analytic_roofline(cfg, sh))
opt  = terms(analytic_roofline(cfg, sh, decode_ws=True))
rows.append(("arctic-480b/decode_32k", "weight-stationary decode (MoE experts stay sharded)",
             base, opt,
             hlo("results/dryrun/arctic-480b__decode_32k__sp.json"),
             hlo("results/hillclimb/arctic_ws/arctic-480b__decode_32k__sp.json")))

# 3. arctic train_4k: 3 ARs/layer -> fused dense+MoE psum (2 ARs/layer)
cfg = get_config("arctic-480b"); sh = INPUT_SHAPES["train_4k"]
base = terms(analytic_roofline(cfg, sh, fused_dense_psum=False))
opt  = terms(analytic_roofline(cfg, sh, fused_dense_psum=True))
rows.append(("arctic-480b/train_4k", "dense-residual psum fused into MoE combine (3->2 AR/layer)",
             base, opt,
             hlo("results/dryrun/arctic-480b__train_4k__sp.json"),
             hlo("results/hillclimb/arctic_fused/arctic-480b__train_4k__sp.json")))

out = []
for name, change, base, opt, h0, h1 in rows:
    dom = base["bottleneck"]
    delta = (base[dom] - opt[dom]) / base[dom] * 100
    rec = {"pair": name, "change": change,
           "before": base, "after": opt,
           "dominant_term": dom, "dominant_delta_pct": round(delta, 1),
           "hlo_before": h0, "hlo_after": h1}
    out.append(rec)
    print(f"== {name}\n   {change}")
    print(f"   before: comp={base['compute']:.4f} mem={base['memory']:.4f} "
          f"coll={base['collective']:.4f}  bottleneck={dom}")
    print(f"   after : comp={opt['compute']:.4f} mem={opt['memory']:.4f} "
          f"coll={opt['collective']:.4f}  bottleneck={opt['bottleneck']}")
    print(f"   dominant term ({dom}) delta: {delta:+.1f}% "
          f"| bound {base['total_bound']:.4f}s -> {opt['total_bound']:.4f}s")
    print(f"   HLO collective ops: {h0['hlo_counts']} -> {h1['hlo_counts']}")

json.dump(out, open("results/hillclimb_report.json", "w"), indent=1)
