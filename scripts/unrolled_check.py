"""Validate the analytic roofline model against an UNROLLED lowering:
qwen1.5-0.5b train_4k with the 23-layer trunk scan fully unrolled, so XLA's
HLO contains every layer's collectives and flops explicitly."""
import os
os.environ["REPRO_SCAN_UNROLL"] = "23"
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "src")
import json

from repro.launch.dryrun import run_combo
from repro.configs import get_config, INPUT_SHAPES
from repro.sharding.analytic import analytic_roofline

rec = run_combo("qwen1.5-0.5b", "train_4k", multi_pod=False)
an = analytic_roofline(get_config("qwen1.5-0.5b"), INPUT_SHAPES["train_4k"])
out = {
    "hlo_unrolled_flops": rec["cost"].get("flops"),
    "hlo_unrolled_coll_bytes": rec["collectives"]["total_bytes"],
    "analytic_flops": an["flops_per_device"],
    "analytic_coll_bytes": an["collective_bytes_per_device"],
    "flops_ratio": rec["cost"].get("flops", 0) / max(an["flops_per_device"], 1),
    "coll_ratio": rec["collectives"]["total_bytes"]
                  / max(an["collective_bytes_per_device"], 1),
}
print(json.dumps(out, indent=1))
with open("results/unrolled_check.json", "w") as f:
    json.dump(out, f, indent=1)
