"""Debug-mesh (8 host devices) sharding check: every family x mode builds,
compiles, and (train) executes with real values, on (2,2) and (2,2,2)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import set_mesh
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeConfig
from repro.launch import steps as STEPS
from repro.launch.mesh import make_debug_mesh

SHAPES = [
    ShapeConfig("t_train", 32, 4, "train"),
    ShapeConfig("t_prefill", 64, 4, "prefill"),
    ShapeConfig("t_decode", 64, 4, "decode"),
]

fails = 0
for multi_pod in (False, True):
    mesh = make_debug_mesh(tp=2, dp=2, multi_pod=multi_pod)
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        for shape in SHAPES:
            t0 = time.time()
            tag = f"{arch} {shape.name} {'mp' if multi_pod else 'sp'}"
            try:
                if shape.mode == "train":
                    fn, args, _ = STEPS.build_train_step(
                        cfg, mesh, shape, multi_pod=multi_pod)
                elif shape.mode == "prefill":
                    fn, args, _ = STEPS.build_prefill_step(
                        cfg, mesh, shape, multi_pod=multi_pod)
                else:
                    fn, args, _ = STEPS.build_decode_step(
                        cfg, mesh, shape, multi_pod=multi_pod)
                with set_mesh(mesh):
                    compiled = fn.lower(*args).compile()
                print(f"OK  {tag}  ({time.time()-t0:.1f}s)", flush=True)
            except Exception as e:
                fails += 1
                import traceback; traceback.print_exc()
                print(f"FAIL {tag}: {type(e).__name__} {str(e)[:200]}", flush=True)
print("fails:", fails)
raise SystemExit(1 if fails else 0)
