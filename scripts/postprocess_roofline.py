"""Augment results/dryrun/*.json with the analytic (trip-count-correct)
roofline terms + final bottleneck/table fields. Produces
results/roofline_table.json + markdown for EXPERIMENTS.md §Roofline."""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.configs import INPUT_SHAPES, get_config
from repro.sharding.analysis import (HBM_BW, ICI_BW, ICI_LINKS,
                                     PEAK_FLOPS_BF16, analytic_model_flops)
from repro.sharding.analytic import analytic_roofline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.out, "*__sp.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "ok": False, "error": rec.get("error", "")[:120]})
            continue
        cfg = get_config(rec["arch"])
        shape = INPUT_SHAPES[rec["shape"]]
        an = analytic_roofline(cfg, shape, tp=16, dp=16, pods=1)
        t_comp = an["flops_per_device"] / PEAK_FLOPS_BF16
        t_mem = an["hbm_bytes_per_device"] / HBM_BW
        t_coll = an["collective_bytes_per_device"] / (ICI_BW * ICI_LINKS)
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        bottleneck = max(terms, key=terms.get)
        model_flops = analytic_model_flops(cfg, shape)
        useful = model_flops / max(an["flops_per_device"] * 256, 1)
        hlo = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "ok": True,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "bottleneck": bottleneck,
            "model_flops": model_flops,
            "useful_flops_ratio": min(useful, 1.0),
            "params_bytes_per_device": an["params_bytes_per_device"],
            "hlo_flops_scanbody": hlo["flops_per_device"],
            "hlo_coll_bytes_scanbody": hlo["collective_bytes_per_device"],
            "temp_bytes": rec["memory"].get("temp_size_in_bytes"),
            "args_bytes": rec["memory"].get("args_bytes_per_device"),
            "compile_s": rec["compile_s"],
        })

    with open("results/roofline_table.json", "w") as f:
        json.dump(rows, f, indent=1)

    if args.md:
        print("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
              "bottleneck | useful | args GB/dev |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            if not r["ok"]:
                print(f"| {r['arch']} | {r['shape']} | FAILED: {r['error']} "
                      "| | | | | |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4g} | "
                  f"{r['t_memory_s']:.4g} | {r['t_collective_s']:.4g} | "
                  f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
                  f"{(r['args_bytes'] or 0)/1e9:.2f} |")
    else:
        print(f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
              f"{'t_coll':>9s} {'bottleneck':>11s} {'useful':>7s}")
        for r in rows:
            if not r["ok"]:
                print(f"{r['arch']:24s} {r['shape']:12s} FAILED")
                continue
            print(f"{r['arch']:24s} {r['shape']:12s} {r['t_compute_s']:9.4f} "
                  f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
                  f"{r['bottleneck']:>11s} {r['useful_flops_ratio']:7.3f}")


if __name__ == "__main__":
    main()
