#!/usr/bin/env bash
# Reproducible tier-1 run: install dev extras (best-effort: the suite
# degrades gracefully — hypothesis-only modules importorskip) and run the
# ROADMAP verify command.
#
# Usage: scripts/run_tier1.sh [--smoke] [pytest args...]
#   --smoke  additionally exercise the device-resident path end-to-end:
#            a 2-round FedSTIL simulation on engine="stacked", the
#            `--only relevance` kernel-bench sweep, a 1-eval smoke of
#            the batched eval-round bench (device vs host-loop parity),
#            the wire-codec comm bench at C=5 (1-round encode/decode
#            host-vs-batched parity assert), a 2-round engine="sharded"
#            simulation on a forced 8-device host mesh (stacked-parity
#            assert), the mesh scaling bench at C=100
#            (sharded-vs-stacked aggregate parity), and a tiny-gallery
#            retrieval-serving smoke (int8 + ivf shortlist + naive
#            paths, exact fp32-vs-numpy-oracle rank parity, full-probe
#            ivf recall == 1.0), and an observability smoke (2-round
#            stacked sim traced to JSONL, report CLI parses it, tracing
#            overhead gate <2%).
set -euo pipefail
cd "$(dirname "$0")/.."

SMOKE=0
if [[ "${1:-}" == "--smoke" ]]; then
    SMOKE=1
    shift
fi

python -m pip install -q -r requirements-dev.txt \
    || echo "warning: dev extras not installed (offline?); continuing" >&2

# fast style/import gate (best-effort: the container image ships no ruff
# wheel; repro.analysis.lint below enforces the unused-import class anyway)
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests
else
    echo "warning: ruff not installed; skipping style gate" >&2
fi

# static-analysis gate: trace every registered program and run the jaxpr +
# convention lints (zero non-baselined findings required)
echo "=== static analysis: repro.analysis.lint ==="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis.lint

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

if [[ "$SMOKE" == "1" ]]; then
    echo "=== smoke: 2-round engine=\"stacked\" FedSTIL simulation ==="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark

from repro.federated import run_simulation

bench = FederatedReIDBenchmark(n_clients=3, n_tasks=2, n_identities=40,
                               ids_per_task=8, samples_per_id=6, seed=0)
cfg = EdgeModelConfig(n_classes=bench.n_classes)
res = run_simulation(FedSTIL(cfg, n_clients=3, epochs=2), bench,
                     rounds=2, eval_every=2, engine="stacked", verbose=True)
assert res.rounds, "stacked smoke produced no eval rounds"
print(f"stacked smoke OK: mAP={res.final('mAP'):.4f} "
      f"server={res.server_time_s*1e3:.1f}ms")
EOF
    echo "=== smoke: relevance bench sweep ==="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.kernels_bench --only relevance
    echo "=== smoke: batched eval round (device vs host loop) ==="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.eval_round --smoke
    echo "=== smoke: wire-codec comm round (host loop vs batched, parity) ==="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.comm_round --smoke
    echo "=== smoke: 2-round engine=\"sharded\" simulation, 8-device mesh ==="
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import jax
assert jax.device_count() == 8, jax.device_count()
from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.federated import run_simulation

bench = FederatedReIDBenchmark(n_clients=3, n_tasks=2, n_identities=40,
                               ids_per_task=8, samples_per_id=6, seed=0)
cfg = EdgeModelConfig(n_classes=bench.n_classes)
mk = lambda: FedSTIL(cfg, n_clients=3, epochs=2, wire_dtype="float32")
sharded = run_simulation(mk(), bench, rounds=2, eval_every=2,
                         engine="sharded")
stacked = run_simulation(mk(), bench, rounds=2, eval_every=2,
                         engine="stacked")
assert abs(sharded.final("mAP") - stacked.final("mAP")) < 1e-6
assert sharded.comm.total_c2s == stacked.comm.total_c2s
print(f"sharded smoke OK: 8 devices, C=3 padded, "
      f"mAP={sharded.final('mAP'):.4f} == stacked, comm bytes equal")
EOF
    echo "=== smoke: mesh scaling bench (stacked vs sharded aggregate) ==="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.mesh_round --smoke
    echo "=== smoke: retrieval serving (int8 + ivf + naive, oracle parity) ==="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.serve_bench --smoke
    echo "=== smoke: observability (traced sim -> report CLI, overhead gate) ==="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json, subprocess, sys, tempfile
from pathlib import Path

from repro.core import FedSTIL
from repro.core.edge_model import EdgeModelConfig
from repro.data import FederatedReIDBenchmark
from repro.federated import run_simulation
from repro.obs.report import summarize
from repro.obs.trace import RunLog

out = Path(tempfile.mkdtemp()) / "obs_run.jsonl"
bench = FederatedReIDBenchmark(n_clients=3, n_tasks=2, n_identities=40,
                               ids_per_task=8, samples_per_id=6, seed=0)
cfg = EdgeModelConfig(n_classes=bench.n_classes)
res = run_simulation(FedSTIL(cfg, n_clients=3, epochs=2), bench,
                     rounds=2, eval_every=2, engine="stacked",
                     trace=str(out))
events = RunLog.read(out)
s = summarize(events)
assert s["events"]["spans"] > 0, "traced sim recorded no spans"
assert "round.server" in s["phases"], sorted(s["phases"])
assert "server.relevance" in s["stages"], sorted(s["stages"])
assert isinstance(s["clients"].get("staleness"), list), s["clients"]
# the report CLI must parse the same JSONL end-to-end
cli = subprocess.run(
    [sys.executable, "-m", "repro.obs.report", str(out), "--json"],
    capture_output=True, text=True, check=True)
parsed = json.loads(cli.stdout)
assert parsed["events"] == s["events"]
print(f"obs smoke OK: {s['events']['spans']} spans, "
      f"{s['events']['metrics']} metrics, report CLI parses")

# off-by-default-cheap: re-measure the tracing tax (small C: quick)
from benchmarks.server_round import measure_overhead
overhead, _ = measure_overhead(C=20, iters=4, repeats=2)
assert overhead["pass"], f"tracing overhead gate FAILED: {overhead}"
print(f"overhead gate OK: {overhead['overhead_frac']*100:.2f}% "
      f"< {overhead['gate']*100:.0f}% @C={overhead['C']}")
EOF
fi
