#!/usr/bin/env bash
# Reproducible tier-1 run: install dev extras (best-effort: the suite
# degrades gracefully — hypothesis-only modules importorskip) and run the
# ROADMAP verify command. Usage: scripts/run_tier1.sh [pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
    || echo "warning: dev extras not installed (offline?); continuing" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
