"""First-principles roofline model per (arch x shape x mesh).

Why this exists: XLA's cost_analysis on CPU counts a ``lax.scan`` body ONCE
(trip count is erased in the while-loop), so HLO-parsed FLOPs / collective
bytes undercount scanned-layer programs by ~L. The dry-run keeps the parsed
numbers (spec'd), and THIS model supplies the trip-count-correct terms. It is
validated against an UNROLLED lowering spot-check (scripts/unrolled_check.py,
EXPERIMENTS.md §Dry-run) — the two agree within ~15% where unrolling is
feasible.

All quantities are per-device per-step. Collectives use ring-algorithm wire
bytes. Hardware: TPU v5e (197 TF/s bf16, 819 GB/s HBM, 4x ~50 GB/s ICI).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import LONG_CONTEXT_WINDOW, ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


def _ring_ar(msg_bytes: float, g: int) -> float:
    return 2.0 * msg_bytes * (g - 1) / max(g, 1)


def _ring_ag(full_bytes: float, g: int) -> float:
    return full_bytes * (g - 1) / max(g, 1)


@dataclasses.dataclass
class Terms:
    flops: float = 0.0          # per device
    hbm: float = 0.0            # bytes per device
    coll: float = 0.0           # wire bytes per device

    def add(self, flops=0.0, hbm=0.0, coll=0.0):
        self.flops += flops
        self.hbm += hbm
        self.coll += coll


def _family_layer(cfg: ModelConfig, B_loc, S, tp, *, train_adaptive=False,
                  fused_dense_psum=True):
    """(flops, hbm, coll) for ONE trunk layer forward on one device.
    train_adaptive=True multiplies compute by 3 (fwd+bwd) and adds the
    backward TP all-reduces (Megatron: 2 fwd + 2 bwd per layer).
    fused_dense_psum=False: pre-hillclimb arctic baseline where the
    dense-residual MLP had its own third all-reduce."""
    d, hd = cfg.d_model, cfg.hd
    H = cfg.padded_heads(tp)
    KV = cfg.n_kv_heads
    tok = B_loc * S
    t = Terms()
    act = B_loc * S * d * BF16                 # one activation tensor

    if cfg.family == "ssm":                    # rwkv6
        # time-mix: 4 projections d x d + out, head-sharded; wkv scan
        t.add(flops=2 * tok * d * (5 * d) / tp)
        t.add(flops=4 * tok * (hd if cfg.rwkv_head_size else 64)
              * cfg.d_model / tp)              # wkv state update+readout
        # channel mix: d*f in + f*d out (+ gate d*d replicated)
        t.add(flops=2 * tok * d * (2 * cfg.d_ff) / tp + 2 * tok * d * d)
        n_ar = 2                               # time-mix out + channel out
    elif cfg.family == "hybrid":               # mamba2 trunk layer
        di = cfg.d_inner
        ds = cfg.ssm_state
        nh = di // cfg.ssm_head_dim
        t.add(flops=2 * tok * d * (2 * di + 2 * ds + nh) / tp)   # in-proj
        t.add(flops=5 * tok * (di // tp) * ds)                   # ssm scan
        t.add(flops=2 * tok * di * d / tp)                       # out-proj
        n_ar = 1
    else:
        # attention projections
        t.add(flops=2 * tok * d * (H * hd + 2 * KV * hd + H * hd) / tp)
        # attention quadratic (causal halves)
        causal_f = 0.5 if cfg.causal else 1.0
        t.add(flops=4 * B_loc * S * S * (H / tp) * hd * causal_f)
        if cfg.n_experts:                      # MoE FFN (top-k, expert-par)
            t.add(flops=2 * tok * cfg.top_k * (3 * d * cfg.d_ff) / tp)
            t.add(flops=2 * tok * d * cfg.n_experts)             # router
            if cfg.dense_residual:
                t.add(flops=2 * tok * (3 * d * (cfg.dense_ff or cfg.d_ff)) / tp)
        else:
            n_mats = 3 if cfg.act == "swiglu" else 2
            t.add(flops=2 * tok * d * (n_mats * cfg.d_ff) / tp)
        n_ar = 2                               # attn out + ffn out
        if cfg.dense_residual and not fused_dense_psum:
            n_ar = 3                           # pre-fusion arctic baseline

    mult = 3.0 if train_adaptive else 1.0
    t.flops *= mult
    n_ar_total = n_ar * (2 if train_adaptive else 1)
    t.add(coll=n_ar_total * _ring_ar(act, tp))
    # activation traffic: ~6 tensor read/writes per layer (fwd)
    t.add(hbm=6 * act * mult)
    return t


def _layer_param_bytes(cfg: ModelConfig, tp: int) -> float:
    """Per-device parameter bytes of ONE trunk layer."""
    d, hd = cfg.d_model, cfg.hd
    H = cfg.padded_heads(tp)
    KV = cfg.n_kv_heads
    if cfg.family == "ssm":
        n = d * 5 * d / tp + d * (2 * cfg.d_ff) / tp + d * d
    elif cfg.family == "hybrid":
        di = cfg.d_inner
        n = d * (2 * di + 2 * cfg.ssm_state + di // cfg.ssm_head_dim) / tp \
            + di * d / tp
    else:
        attn = d * (2 * H * hd) / tp + d * 2 * KV * hd / max(
            tp if KV >= tp else 1, 1)
        if cfg.n_experts:
            ffn = cfg.n_experts * 3 * d * cfg.d_ff / tp + d * cfg.n_experts
            if cfg.dense_residual:
                ffn += 3 * d * (cfg.dense_ff or cfg.d_ff) / tp
        else:
            ffn = (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff / tp
        n = attn + ffn
    return n * BF16


def analytic_roofline(cfg: ModelConfig, shape: ShapeConfig, *, tp=16, dp=16,
                      pods=1, fused_dense_psum=True,
                      decode_ws=False, ws_fused=True) -> Dict[str, float]:
    d = cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    dpp = dp * pods
    B_loc = B // dpp if B % dpp == 0 else (
        B // dp if B % dp == 0 else B)             # replicate if indivisible
    Vp = cfg.padded_vocab()
    L = cfg.n_layers
    n_ad = cfg.n_adaptive_layers
    t = Terms()

    params_dev = L * _layer_param_bytes(cfg, tp) + 2 * Vp * d * BF16 / tp
    if cfg.n_enc_layers:
        params_dev += cfg.n_enc_layers * _layer_param_bytes(cfg, tp)
    if cfg.fsdp:
        params_dev /= dp

    if shape.mode in ("train", "prefill"):
        train = shape.mode == "train"
        S_eff = S - (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
        # trunk layers (fwd only under the FedSTIL frozen split)
        trunk = _family_layer(cfg, B_loc, S, tp,
                              fused_dense_psum=fused_dense_psum)
        t.add(trunk.flops * (L - n_ad), trunk.hbm * (L - n_ad),
              trunk.coll * (L - n_ad))
        ad = _family_layer(cfg, B_loc, S, tp, train_adaptive=train,
                           fused_dense_psum=fused_dense_psum)
        t.add(ad.flops * n_ad, ad.hbm * n_ad, ad.coll * n_ad)
        if cfg.family == "encdec":
            enc = _family_layer(cfg, B_loc, cfg.enc_seq or 1536, tp)
            t.add(enc.flops * cfg.n_enc_layers, enc.hbm * cfg.n_enc_layers,
                  enc.coll * cfg.n_enc_layers)
            # cross attention (S x enc_seq) per decoder layer
            t.add(flops=4 * B_loc * S * 1536 * (cfg.padded_heads(tp) / tp)
                  * cfg.hd * L)
        # embedding psum + head
        act = B_loc * S_eff * d * BF16
        t.add(coll=_ring_ar(act, tp))
        if train:
            t.add(flops=3 * 2 * B_loc * S_eff * d * Vp / tp)
            t.add(coll=2 * _ring_ar(B_loc * S_eff * F32, tp))  # CE lse+tgt
            # adaptive grads auto-psum over data (+pod)
            ad_bytes = (n_ad * _layer_param_bytes(cfg, tp)
                        + Vp * d * BF16 / tp) * 2  # alpha+A, f32/2≈bf16x2
            t.add(coll=_ring_ar(ad_bytes * 2, dpp))
            # optimizer state rw (adaptive only, f32 m+v)
            t.add(hbm=ad_bytes * 2 * 3)
        else:
            t.add(flops=2 * B_loc * 1 * d * Vp / tp)           # last token
        # weights read once (+ fsdp gather traffic)
        t.add(hbm=params_dev * (2 if cfg.fsdp else 1))
        if cfg.fsdp:
            t.add(coll=_ring_ag(params_dev * dp, dp))

    else:  # decode: ONE token, cache of length S (or ring window)
        ring = shape.name == "long_500k" and cfg.family != "ssm"
        S_cache = LONG_CONTEXT_WINDOW if ring else S
        tok = B_loc
        H = cfg.padded_heads(tp)
        hd = cfg.hd
        KV = cfg.n_kv_heads

        # per layer: projections (head-sharded) + cache attention (seq/tp)
        if cfg.family == "ssm":
            t.add(flops=L * (2 * tok * d * 5 * d / tp
                             + 4 * tok * (d / tp) * cfg.rwkv_head_size
                             + 2 * tok * d * 2 * cfg.d_ff / tp))
            state_bytes = L * B_loc * (d / tp) * cfg.rwkv_head_size * F32
            t.add(hbm=2 * state_bytes)
            t.add(coll=L * 2 * _ring_ar(B_loc * d * BF16, tp))
        elif cfg.family == "hybrid":
            di = cfg.d_inner
            n_groups = L // cfg.attn_every
            t.add(flops=L * (2 * tok * d * (2 * di) / tp + 5 * tok * (di / tp)
                             * cfg.ssm_state + 2 * tok * di * d / tp))
            state_bytes = L * B_loc * (di / tp) * cfg.ssm_state * F32
            cache_bytes = (n_groups * B_loc * (S_cache / tp) * KV * hd
                           * 2 * BF16)
            t.add(hbm=2 * state_bytes + cache_bytes)
            t.add(flops=n_groups * 4 * tok * (S_cache / tp) * KV
                  * (H // KV) * hd)
            t.add(coll=L * _ring_ar(B_loc * d * BF16, tp)
                  + n_groups * 2 * _ring_ar(B_loc * H * hd * F32, tp))
        else:
            n_dec = L
            proj = 2 * tok * d * (2 * H * hd + 2 * KV * hd) / tp
            if cfg.n_experts:
                ffn = 2 * tok * cfg.top_k * 3 * d * cfg.d_ff / tp
                if cfg.dense_residual:
                    ffn += 2 * tok * 3 * d * (cfg.dense_ff or cfg.d_ff) / tp
            else:
                ffn = 2 * tok * (3 if cfg.act == "swiglu" else 2) * d \
                    * cfg.d_ff / tp
            attn_read = 4 * tok * (S_cache / tp) * KV * max(H // KV, 1) * hd
            t.add(flops=n_dec * (proj + ffn + attn_read))
            cache_bytes = n_dec * B_loc * (S_cache / tp) * KV * hd * 2 * BF16
            if cfg.family == "encdec":
                cache_bytes += n_dec * B_loc * (1536 / tp) * KV * hd * 2 * BF16
                t.add(flops=n_dec * 4 * tok * (1536 / tp) * KV
                      * max(H // KV, 1) * hd)
            t.add(hbm=cache_bytes)       # read whole cache
            # flash-decode merge (m,l,o in f32) + layer output psums
            merge = B_loc * H * hd * F32 + 2 * B_loc * H * F32
            t.add(coll=n_dec * (2 * _ring_ar(merge, tp)
                                + 2 * _ring_ar(B_loc * d * BF16, tp)))
        # head + embed
        t.add(flops=2 * tok * d * Vp / tp)
        t.add(coll=_ring_ar(B_loc * d * BF16, tp))
        # weights read once per token step
        if cfg.fsdp and decode_ws:
            # weight-stationary: weights stay sharded; activations move.
            B_tot = B_loc * dp
            act = B_tot * d * BF16
            hd_ = cfg.hd
            H_ = cfg.padded_heads(tp)
            qkv_cols = H_ * hd_ / tp + 2 * KV * hd_
            mlp_cols = (2 if cfg.act == "swiglu" else 1) * (
                cfg.top_k * cfg.d_ff / tp if cfg.n_experts else cfg.d_ff / tp)
            if ws_fused:
                # iteration 2: one x-gather + one psum per projection group
                per_layer = (2 * _ring_ag(act, dp)
                             + _ring_ar(B_tot * qkv_cols * BF16, dp)
                             + _ring_ar(B_tot * mlp_cols * BF16, dp)
                             + _ring_ar(B_loc * d / dp * BF16, tp)
                             + 2 * _ring_ag(B_loc * d * BF16, dp))
            else:
                # iteration 1: separate gather+psum per weight matrix
                per_layer = (5 * _ring_ag(act, dp)
                             + 3 * _ring_ar(B_tot * qkv_cols / 3 * BF16, dp)
                             + 2 * _ring_ar(B_tot * mlp_cols / 2 * BF16, dp)
                             + _ring_ar(B_loc * d / dp * BF16, tp)
                             + 2 * _ring_ag(B_loc * d * BF16, dp))
            t.add(hbm=params_dev)
            t.add(coll=L * per_layer)
        else:
            t.add(hbm=params_dev * (2 if cfg.fsdp else 1))
            if cfg.fsdp:
                t.add(coll=_ring_ag(params_dev * dp, dp))

    return {"flops_per_device": t.flops, "hbm_bytes_per_device": t.hbm,
            "collective_bytes_per_device": t.coll,
            "params_bytes_per_device": params_dev}
