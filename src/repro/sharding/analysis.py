"""Compiled-artifact analysis: per-device bytes, HLO cost, and collective
traffic parsed from the lowered/compiled HLO text (roofline §Roofline).

collective_bytes is NOT in cost_analysis — we parse the (optimized when
available) HLO and sum the bytes every collective moves per device, using
ring-algorithm wire-byte formulas and the replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# numpy/ml_dtypes name -> HLO short name (same width table as above, so
# the jaxpr-level analyses in repro.analysis price dtypes identically to
# the HLO-level parsing here)
_NP_TO_HLO = {
    "float64": "f64", "float32": "f32", "float16": "f16", "bfloat16": "bf16",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
    "int64": "s64", "uint64": "u64", "int32": "s32", "uint32": "u32",
    "int16": "s16", "uint16": "u16", "int8": "s8", "uint8": "u8",
    "bool": "pred", "complex64": "c64", "complex128": "c128",
}


def dtype_bytes(dtype) -> int:
    """Bytes per element for a numpy/ml_dtypes dtype (table-driven, with
    ``itemsize`` as the fallback for exotic types)."""
    name = getattr(dtype, "name", str(dtype))
    hlo = _NP_TO_HLO.get(name, name)
    if hlo in _DTYPE_BYTES:
        return _DTYPE_BYTES[hlo]
    return int(getattr(dtype, "itemsize", 4))


def aval_bytes(shape, dtype) -> int:
    """Total bytes of an abstract value (shape x dtype width)."""
    n = 1
    for d in shape:
        n *= int(d)
    return n * dtype_bytes(dtype)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{?\[?(\d+)[,x](\d+)\]?")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:  # iota form: replica_groups=[G,N]<=[...]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-device wire bytes for each collective op in the HLO.

    Ring formulas (size = result buffer bytes, g = group size):
      all-gather:     result is gathered -> moves size*(g-1)/g
      all-reduce:     2 * size * (g-1)/g
      reduce-scatter: input = result*g   -> moves size*(g-1)  [input-relative]
      all-to-all:     size * (g-1)/g
      collective-permute: size
    """
    by_bytes: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    by_count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        g = max(_group_size(line), 1)
        if kind == "all-gather":
            moved = size * (g - 1) // max(g, 1)
        elif kind == "all-reduce":
            moved = 2 * size * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            moved = size * (g - 1)
        elif kind == "all-to-all":
            moved = size * (g - 1) // max(g, 1)
        else:
            moved = size
        by_bytes[kind] += moved
        by_count[kind] += 1
    return CollectiveStats(by_bytes, by_count)


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LINKS = 4          # v5e: ~4 usable ICI directions per chip (2D torus)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    model_flops: float = 0.0       # analytic 6*N*D (global, all devices)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / (ICI_BW * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.n_devices
        return (self.model_flops / total) if total else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analytic_model_flops(cfg, shape) -> float:
    """Theoretical-minimum model FLOPs: 2*N_active*D forward; training adds
    backward ONLY over the FedSTIL-adaptive slice (frozen trunk!), i.e.
    +4*N_adaptive*D. (Plain 6*N*D would be the full-fine-tune number.)"""
    n_active = cfg.active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    if shape.mode == "train":
        return (2.0 * n_active + 4.0 * cfg.adaptive_active_params()) * tokens
    return 2.0 * n_active * tokens
