"""Sharding rules: map every parameter / batch / cache leaf to a
PartitionSpec by its tree path (Megatron TP + optional FSDP over data).

The model code (repro/models) consumes *local* shards inside shard_map and
emits collectives via AxisCtx; these specs define the global layout the
dry-run hands to jax.jit/shard_map.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# stacked-subtree prefixes (leading layer dim)
_STACKED = ("layers", "adaptive_layers", "enc_layers")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(cfg: ModelConfig, path: str, shape, *, tp_axis="model",
               fsdp_axis: Optional[str] = "data", tp_size: int = 16) -> P:
    """PartitionSpec for one parameter leaf, identified by its path string.

    The path may be prefixed arbitrarily (trainable/alpha/..., opt m/v, B) —
    rules match on the trailing components.
    """
    fs = fsdp_axis if cfg.fsdp else None
    stacked = any(s in path.split("/") for s in _STACKED)
    kv_sharded = cfg.n_kv_heads >= tp_size  # else replicated + group-sliced

    def lead(*spec):
        return P(*( (None,) + spec if stacked else spec ))

    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    # ---- attention ----
    if parent in ("attn", "cross"):
        if name == "wq":
            return lead(fs, tp_axis)
        if name in ("wk", "wv"):
            return lead(fs, tp_axis) if kv_sharded else lead(fs, None)
        if name == "wo":
            return lead(tp_axis, fs)
        if name == "bq":
            return lead(tp_axis)
        if name in ("bk", "bv"):
            return lead(tp_axis) if kv_sharded else lead(None)
        if name in ("qnorm", "knorm"):
            return lead(None)

    # ---- dense mlp / moe dense residual ----
    if parent in ("mlp", "dense"):
        if name in ("wi", "wg"):
            return lead(fs, tp_axis)
        if name == "wo":
            return lead(tp_axis, fs)

    # ---- moe experts ----
    if parent == "moe":
        if name == "router":
            return lead(None, None)
        if name in ("wi", "wg"):                    # (E, d, f)
            return lead(tp_axis, None, fs)
        if name == "wo":                            # (E, f, d)
            return lead(tp_axis, fs, None)
    if "moe/dense" in path:
        pass  # handled by parent == "dense"

    # ---- mamba ----
    if parent == "mamba":
        if name in ("w_zx", "w_dt"):
            return lead(fs, tp_axis)
        if name == "w_bc":
            return lead(fs, None)
        if name in ("dt_bias", "A_log", "D", "conv_b", "norm"):
            return lead(tp_axis)
        if name == "conv_w":
            return lead(None, tp_axis)
        if name == "w_out":
            return lead(tp_axis, fs)

    # ---- rwkv time/channel mix ----
    if parent == "time":
        if name in ("wr", "wk", "wv", "wg"):
            return lead(fs, tp_axis)
        if name == "wo":
            return lead(tp_axis, fs)
        if name in ("u", "ln_scale", "ln_bias"):
            return lead(tp_axis)
        if name in ("mu", "w0", "Aw", "Bw"):
            return lead(*([None] * (len(shape) - (1 if stacked else 0))))
    if parent == "chan":
        if name == "wk":
            return lead(fs, tp_axis)
        if name == "wv":
            return lead(tp_axis, fs)
        if name in ("wr", "mu"):
            return lead(*([None] * (len(shape) - (1 if stacked else 0))))

    # ---- embedding / head ----
    if parent == "embed" and name == "table":
        return P(tp_axis, None)
    if parent == "head" and name == "w":
        return P(None, tp_axis)

    # ---- norms, scalars, anything else: replicated ----
    return P(*([None] * len(shape)))


def tree_param_specs(cfg: ModelConfig, tree, **kw):
    """PartitionSpec pytree matching ``tree`` (of arrays/ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [param_spec(cfg, _path_str(path), leaf.shape, **kw)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# federated engine mesh: the layout source of truth for engine="sharded"
# ---------------------------------------------------------------------------
#
# Axis names are fixed repo-wide: "data" shards the client dim (every
# stacked (C, ...) leaf puts its leading dim here), "model" shards the
# flattened parameter dim of the (C, P) server matrices. On the CPU/host
# meshes we run today model=1 (P stays whole per device); the axis exists
# so the layout generalizes to real multi-chip meshes without respelling
# any spec.

ENGINE_AXES = ("data", "model")


def engine_mesh(devices=None, *, model: int = 1):
    """The engine's Mesh(("data", "model")): all devices on the client
    axis by default. ``run_simulation(engine="sharded")`` builds exactly
    this; tests/benches pass an explicit device list to shrink it."""
    if devices is None:
        devices = jax.devices()
    n = len(devices)
    if n % model != 0:
        raise ValueError(f"{n} devices not divisible by model={model}")
    import numpy as _np
    return jax.sharding.Mesh(
        _np.asarray(devices).reshape(n // model, model), ENGINE_AXES)


def padded_clients(C: int, mesh) -> int:
    """Smallest Cp >= C divisible by the data-axis size. Clients [C, Cp)
    are padding: zero batches, validity mask 0, never pushed into the
    relevance ring (so their W rows/cols are zero and the nz machinery
    keeps their base untouched)."""
    d = mesh.shape["data"]
    return ((C + d - 1) // d) * d


def client_row_spec(ndim: int, *, client_axis: str = "data") -> P:
    """Leading-client-dim spec: rows over ``client_axis``, rest whole."""
    return P(*((client_axis,) + (None,) * (ndim - 1)))


def stacked_tree_specs(tree, *, client_axis: str = "data"):
    """Spec pytree for any stacked (C, ...) state/batch/buffer pytree:
    every leaf's leading client dim over ``client_axis``."""
    return jax.tree.map(
        lambda l: client_row_spec(l.ndim, client_axis=client_axis), tree)


def named_shardings(mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# federated server: stacked (C, P) aggregate specs
# ---------------------------------------------------------------------------


def stacked_aggregate_specs(*, client_axis: str = "data",
                            param_axis: Optional[str] = "model"):
    """PartitionSpecs for the fused server aggregate B = Wn @ Θ at C ≫ 100.

    Θ (C, P) shards its client rows over ``client_axis`` (each device holds
    a client block resident between rounds) and optionally its parameter
    columns over ``param_axis``; W (C, C) shards its *columns* over the
    client axis to line up with Θ's contracted dim, so GSPMD lowers the
    matmul to per-device partial products + one reduce over the client
    axis. The (C, P) aggregate output B is *row*-sharded like Θ — a
    reduce-scatter instead of an all-reduce — so each device ends the
    round holding exactly its own clients' new bases (Cp/d × P live
    bytes, never the full C × P). The (C, C) normalized-relevance
    output is tiny and replicated (the host reads it back for last_W).
    """
    return {
        "w": P(None, client_axis),
        "thetas": P(client_axis, param_axis),
        "out": P(client_axis, param_axis),
        "wn": P(None, None),
    }


def stacked_eval_specs(*, client_axis: str = "data"):
    """PartitionSpecs for the batched (C x tasks) retrieval eval at C ≫ 1000.

    Every input and output carries a leading client dim sharded over
    ``client_axis``; the task/query/gallery content dims stay unsharded.
    Each device then evaluates its own block of clients end-to-end (feature
    heads, distance matrices, ranking, metrics) with NO cross-client
    collectives — retrieval eval is embarrassingly parallel over clients,
    unlike the Eq. 6 aggregate which contracts the client dim.
    """
    def row(nd):
        return P(*((client_axis,) + (None,) * (nd - 1)))

    return {
        "qf": row(4),          # (C, T, Q, D) query prototypes/features
        "qids": row(3),        # (C, T, Q)
        "task_mask": row(2),   # (C, T)
        "gf": row(3),          # (C, G, D) gallery prototypes/features
        "gids": row(2),        # (C, G)
        "gmask": row(2),       # (C, G)
        "metrics": row(2),     # (C, T) per metric key
    }


def serving_index_specs(*, client_axis: str = "data"):
    """PartitionSpecs for the serving index's device image (repro.serving).

    Same shape contract as the stacked eval: EVERY resident array —
    query batches, the flat int8 image, and the IVF bucket image
    (centroids, bucket-major codes, packed sidecar, inverted lists) —
    leads with the client dim, row-sharded over ``client_axis``. Each
    device serves its own block of clients' galleries end-to-end
    (featurize, cluster-assign, shortlist, rank) with no cross-client
    collectives; bucket/row content dims stay unsharded.
    """
    def row(nd):
        return P(*((client_axis,) + (None,) * (nd - 1)))

    return {
        # query operands
        "qp": row(3),          # (C, B, proto_dim)
        "qmask": row(2),       # (C, B)
        "bn_mu": row(2),       # (C, F)
        "bn_sd": row(2),       # (C, F)
        # flat image (exact int8/fp32 paths)
        "gq": row(3),          # (C, G, F) int8 codes
        "gscale": row(2),      # (C, G)
        "gn2": row(2),         # (C, G)
        "gids": row(2),        # (C, G)
        "gf": row(3),          # (C, G, F) optional fp32 rows
        # IVF image (approximate path)
        "cent": row(3),        # (C, nlist, F)
        "cn2": row(2),         # (C, nlist)
        "bq": row(4),          # (C, nlist, bcap, F) int8 bucket rows
        "pack": row(4),        # (C, nlist, 3, bcap) packed sidecar
        "binv": row(3),        # (C, nlist, bcap) inverted lists
    }


def stacked_eval_theta_specs(theta, *, client_axis: str = "data"):
    """PartitionSpec pytree for a stacked (C, ...) eval-theta pytree:
    client rows over ``client_axis``, everything else replicated."""
    return jax.tree.map(
        lambda l: P(*((client_axis,) + (None,) * (l.ndim - 1))), theta)


def batch_axes(global_batch: int, dp: int, multi_pod: bool):
    """Which axes the batch dim shards over (None if not divisible)."""
    axes = ("pod", "data") if multi_pod else ("data",)
    total = dp * (2 if multi_pod else 1)
    if global_batch % total == 0:
        return axes if multi_pod else "data"
    if global_batch % dp == 0:   # shard over data only
        return "data"
    return None                   # replicate (long_500k batch=1)


def batch_specs(cfg: ModelConfig, batch_tree, global_batch: int, dp: int,
                multi_pod: bool):
    b = batch_axes(global_batch, dp, multi_pod)

    def spec_for(path, leaf):
        return P(*((b,) + (None,) * (len(leaf.shape) - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_tree)
    specs = [spec_for(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_specs(cfg: ModelConfig, cache_tree, global_batch: int, dp: int,
                multi_pod: bool, *, tp_axis="model"):
    """Decode caches: (L, B, S, KV, hd) -> batch over data, SEQ over model
    (flash-decoding layout); SSM states: heads/channels over model."""
    b = batch_axes(global_batch, dp, multi_pod)

    def spec_for(path, leaf):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        nd = len(leaf.shape)
        if name in ("k", "v"):            # (L, B, S, KV, hd)
            return P(None, b, tp_axis, None, None)
        if name in ("k_scale", "v_scale"):  # (L, B, S, KV)
            return P(None, b, tp_axis, None)
        if name == "h":                   # mamba (L, B, nh, hd, ds)
            return P(None, b, tp_axis, None, None)
        if name == "conv":                # (L, B, k-1, di)
            return P(None, b, None, tp_axis)
        if name == "S":                   # rwkv (L, B, nh, hd, hd)
            return P(None, b, tp_axis, None, None)
        if name in ("x_att", "x_ffn"):    # (L, B, d)
            return P(None, b, None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = [spec_for(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
