from repro.sharding.analysis import Roofline, parse_collectives
from repro.sharding.analytic import analytic_roofline
from repro.sharding.specs import (
    batch_axes,
    batch_specs,
    cache_specs,
    param_spec,
    tree_param_specs,
)
