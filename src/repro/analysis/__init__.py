"""Static analysis of the repo's jitted programs (trace-time lint).

Three layers (see ``analysis/README.md``):

  * ``registry``    — ``@register_program`` decorator + runtime manifest:
    every hot jitted entry point is traceable abstractly (no data, no
    execution) from one place.
  * ``lints``       — jaxpr passes over each traced program: dtype
    widening beyond the declared wire dtypes, convert churn, host
    callbacks inside scanned bodies, non-donated round-carried state,
    dead code, and a static peak-intermediate-bytes estimate checked
    against each program's declared budget.
  * ``conventions`` — AST-level repo conventions: every Pallas kernel is
    paired with a ref oracle + ops dispatcher + parity test, every
    registered fast path names its host oracle, no unused imports, no
    unreached seed modules without an allowlist entry.

CLI gate: ``python -m repro.analysis.lint [--program NAME] [--json]``
(wired into ``scripts/run_tier1.sh``), with ``baseline.json`` suppressing
known findings so new ones fail loudly while old ones burn down.
"""
from repro.analysis.registry import (ProgramSpec, coverage, get_program,
                                     iter_programs, load_all,
                                     register_program, register_runtime)

__all__ = [
    "ProgramSpec", "coverage", "get_program", "iter_programs", "load_all",
    "register_program", "register_runtime",
]
