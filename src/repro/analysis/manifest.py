"""Manifest: registers the jitted closures that only exist at runtime.

The decorator in ``registry`` covers module-level programs; the engines'
hottest programs, though, are closures built per-strategy-instance
(``Strategy._stacked_train_fn``, ``FedSTIL._stacked_server_fns``) or
per-payload-size (``comm.batched.BatchedCodec``'s encode/decode jits).
This module constructs them with tiny concrete configs (bench-scale
abstract shapes, C=100 where the BENCH_*.json sweeps top out) and
registers the *production* jitted callables — so the donation lint sees
the real ``donate_argnums`` and the dtype/callback lints see the real
trace, not a re-implementation.

Importing this module (``registry.load_all()`` does) performs the
registrations; everything here is host-side init at toy sizes, no real
training step ever runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.analysis.registry import register_runtime

_SDS = jax.ShapeDtypeStruct
_F32 = jnp.float32
_I32 = jnp.int32

# bench-scale abstract sizes (the BENCH_*.json sweeps top out at C=100)
_C = 100
_HIST = 6


def _sds_like(tree):
    return jax.tree.map(lambda l: _SDS(l.shape, l.dtype), tree)


def _register_fedstil() -> None:
    import numpy as np

    from repro.core.edge_model import EdgeModelConfig
    from repro.core.fedstil import FedSTIL
    from repro.kernels import ops

    cfg = EdgeModelConfig()
    D = cfg.proto_dim
    strat = FedSTIL(cfg, n_clients=_C, epochs=2)
    # tiny concrete states: _stacked_server_fns flattens an example theta
    # eagerly, and stack_states is the cheapest way to an exact opt-state
    # / extras structure. C is small here; the abstract args re-shape to _C.
    C0 = 4
    states = {c: strat.init_client(jax.random.PRNGKey(c)) for c in range(C0)}
    stacked = strat.stack_states(states)
    theta_example = strat.eval_theta_stacked(stacked)       # (C0, ...) pytree
    relevance, flatten, unflatten = strat._stacked_server_fns(theta_example)
    P = int(np.sum([np.prod(l.shape[1:])
                    for l in jax.tree.leaves(theta_example)]))

    def _stretch(tree):                 # (C0, ...) SDS -> (_C, ...) SDS
        return jax.tree.map(lambda l: _SDS((_C,) + l.shape[1:], l.dtype),
                            tree)

    # the ring push takes the per-client participation mask (all-ones on
    # the stacked engine, the client-validity mask on the sharded engine)
    # plus the per-client staleness counter it carries round-to-round
    ring_args = (_SDS((_C, _HIST, D), _F32), _SDS((_C, _HIST), _F32),
                 _SDS((_C,), _F32),
                 _SDS((_C, D), _F32), _SDS((_C,), _F32))

    register_runtime(
        "federated.fedstil_server_relevance", relevance,
        abstract_args=lambda: (ring_args, {}),
        module="repro.core.fedstil",
        oracle="repro.core.relevance.RelevanceTracker.relevance",
        carry=(0, 1, 2), donate=(0, 1, 2), budget_bytes=64 << 20)

    def server_round(buf, valid, stale, feats, mask, theta_flat):
        """The full staged stacked server round (FedSTIL
        ``server_round_stacked`` data path) as one traceable program:
        ring push + Eq. 4/5 relevance (with its rider telemetry mets),
        the fused Eq. 5→6 kernel, unflatten, and the nz row mask."""
        buf, valid, stale, w_raw, mets = relevance(buf, valid, stale,
                                                   feats, mask)
        b_flat, wn = ops.fused_relevance_aggregate(w_raw, theta_flat,
                                                   backend="ref")
        nz = jnp.sum(wn, axis=1) > 0
        return buf, valid, stale, unflatten(b_flat), nz, mets

    register_runtime(
        "federated.fedstil_server_round", server_round,
        abstract_args=lambda: (ring_args + (_SDS((_C, P), _F32),), {}),
        module="repro.core.fedstil",
        oracle="repro.core.fedstil.FedSTIL.server_round",
        carry=(0, 1, 2), donate=(0, 1, 2), budget_bytes=128 << 20)

    # engine="sharded" server stages, built against a 1x1 engine mesh (the
    # layouts are shape-preserving, so the trace is device-count
    # independent). The composite crosses the flatten->aggregate stage
    # boundary in wire_dtype: the f32->bf16->f32 pair is the sanctioned
    # wire cast of common/precision.py, not convert churn.
    from repro.common.precision import WIRE_CASTS
    strat.mesh = jax.make_mesh((1, 1), ("data", "model"))
    flatten_wire, aggregate = strat._sharded_server_fns(theta_example)

    def sharded_server_round(buf, valid, stale, feats, mask, theta):
        buf, valid, stale, w_raw, mets = relevance(buf, valid, stale,
                                                   feats, mask)
        b_flat, wn = aggregate(w_raw, flatten_wire(theta))
        nz = jnp.sum(wn, axis=1) > 0
        return buf, valid, stale, unflatten(b_flat), nz, mets

    register_runtime(
        "federated.sharded_server_round", sharded_server_round,
        abstract_args=lambda: (
            ring_args + (_stretch(_sds_like(theta_example)),), {}),
        module="repro.core.fedstil",
        oracle="repro.core.fedstil.FedSTIL.server_round",
        carry=(0, 1, 2), donate=(0, 1, 2), budget_bytes=128 << 20,
        sanctioned_casts=WIRE_CASTS)

    epochs, batch = strat.epochs, strat.batch
    register_runtime(
        "federated.stacked_local_train", strat._stacked_train_fn(),
        abstract_args=lambda: ((
            _stretch(_sds_like(stacked.trainable)),
            _stretch(_sds_like(stacked.opt_state)),
            _stretch(_sds_like(strat._stacked_loss_extras(stacked))),
            _SDS((_C, epochs, batch, D), _F32),
            _SDS((_C, epochs, batch), _I32)), {}),
        module="repro.federated.base",
        oracle="repro.federated.base.Strategy._run_epochs",
        # the static liveness estimate is deliberately conservative around
        # the vmap-of-scan autodiff (it keeps VJP residuals live across the
        # whole epoch scan); measured ~584 MB at C=100 on this estimator
        carry=(0, 1), donate=(0, 1), budget_bytes=640 << 20)

    # flatten/unflatten stages ride along so the full staged-jit server
    # structure (see the ROADMAP note on why it is NOT one mega-jit) stays
    # under analysis
    register_runtime(
        "federated.fedstil_server_flatten", flatten,
        abstract_args=lambda: ((_stretch(_sds_like(theta_example)),), {}),
        module="repro.core.fedstil",
        oracle="repro.common.pytree.tree_flatten_stacked",
        budget_bytes=128 << 20)


def _register_comm() -> None:
    from repro.comm.batched import BatchedCodec
    from repro.comm.codec import make_codec

    P = 4096
    codec = BatchedCodec(make_codec("topk+int8"), P)
    enc_args = (_SDS((_C, P), _F32),)
    buffers_sds = jax.eval_shape(codec._enc_sparse, *enc_args)[0]

    register_runtime(
        "comm.batched_encode", codec._enc_sparse,
        abstract_args=lambda: (enc_args, {}),
        module="repro.comm.batched",
        oracle="repro.comm.codec.PipelineCodec.encode",
        budget_bytes=32 << 20)
    register_runtime(
        "comm.batched_encode_keyframe", codec._enc_dense,
        abstract_args=lambda: (enc_args, {}),
        module="repro.comm.batched",
        oracle="repro.comm.codec.PipelineCodec.encode",
        budget_bytes=32 << 20)
    register_runtime(
        "comm.batched_decode", codec._dec_sparse,
        abstract_args=lambda: ((buffers_sds,), {}),
        module="repro.comm.batched",
        oracle="repro.comm.codec.PipelineCodec.decode",
        budget_bytes=32 << 20)


def _register_sharded() -> None:
    # the engine's two standalone mesh programs (the launch CLIs are thin
    # demo harnesses around these — exactly one sharded implementation)
    from repro.core.fedstil import sharded_fused_aggregate
    from repro.federated.base import sharded_eval_fn

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    register_runtime(
        "federated.sharded_aggregate",
        functools.partial(sharded_fused_aggregate, mesh=mesh),
        abstract_args=lambda: ((_SDS((_C, _C), _F32),
                                _SDS((_C, 4096), _F32)), {}),
        module="repro.core.fedstil",
        oracle="repro.kernels.ref.fused_relevance_aggregate_ref",
        budget_bytes=64 << 20)

    from repro.core.edge_model import EdgeModelConfig
    from repro.core import edge_model as EM
    cfg = EdgeModelConfig()
    th = jax.eval_shape(lambda k: EM.init_adaptive_layers(k, cfg),
                        jax.random.PRNGKey(0))
    C, T, Q, G = 8, 3, 16, 96
    th_sds = jax.tree.map(lambda l: _SDS((C,) + l.shape, l.dtype), th)
    register_runtime(
        "federated.sharded_eval",
        sharded_eval_fn(mesh, kernel_backend="ref"),
        abstract_args=lambda: ((th_sds,
                                _SDS((C, T, Q, cfg.proto_dim), _F32),
                                _SDS((C, T, Q), _I32),
                                _SDS((C, T), _F32),
                                _SDS((C, G, cfg.proto_dim), _F32),
                                _SDS((C, G), _I32),
                                _SDS((C, G), _F32)), {}),
        module="repro.federated.base",
        oracle="repro.federated.simulation._eval_round",
        budget_bytes=64 << 20)


_register_fedstil()
_register_comm()
_register_sharded()
