"""Program registry: every hot jitted entry point, traceable abstractly.

A *program* is a traceable callable plus the metadata the lint passes
need: abstract input shapes (a thunk returning ``(args, kwargs)`` of
``jax.ShapeDtypeStruct`` pytrees — kwargs are static config), which
positional args are round-carried state (``carry``) and which the program
donates (``donate``), a peak-intermediate-bytes budget, the dtype set the
program is allowed to touch, and the dotted path of its retained host
oracle.

Module-level functions register with the decorator::

    @register_program("kernels.fused_relevance_aggregate",
                      abstract_args=lambda: ((w_sds, th_sds),
                                             {"backend": "ref"}),
                      oracle="repro.kernels.ref.fused_relevance_aggregate_ref",
                      budget_bytes=8 << 20)
    @functools.partial(jax.jit, static_argnames=("backend",))
    def fused_relevance_aggregate(w, thetas, *, backend=None): ...

Closures built at runtime (``FedSTIL._stacked_server_fns``, the
``BatchedCodec`` encode/decode jits) cannot be decorated at import time;
``analysis/manifest.py`` constructs them with tiny concrete configs and
registers them via ``register_runtime`` when ``load_all()`` runs.

Registering is free at import time: the decorator only records metadata.
Tracing happens lazily, via ``trace(spec)`` (``jax.make_jaxpr`` over the
abstract args — no data ever touches a device).
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import Any, Callable, Dict, List, Optional, Tuple

# dtypes a program may touch unless it declares otherwise: the measured
# wire/compute dtypes (bf16 / int8 / f32) plus the index/mask types every
# jaxpr needs. float64 / int64 / complex are NEVER in a default set — f64
# creep is exactly what the dtype lint exists to catch.
DEFAULT_ALLOWED_DTYPES = frozenset({
    "float32", "bfloat16", "float16", "int8", "uint8", "int32", "uint32",
    "bool",
})

# default peak-intermediate budget, sized for the 2-core CPU runner (the
# bench configs keep live intermediates well under this; mesh configs
# declare their own)
DEFAULT_BUDGET_BYTES = 256 << 20

# modules whose import registers the decorated programs. repro.core leads
# (registers nothing itself): the core <-> federated import cycle only
# resolves when rooted at repro.core, so federated.base must not be the
# first of the pair imported.
PROGRAM_MODULES = (
    "repro.core",
    "repro.kernels.ops",
    "repro.evalreid.batched",
    "repro.federated.base",
    "repro.serving.engine",
    "repro.analysis.manifest",
)


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """One registered program and the invariants the lints check."""

    name: str
    fn: Callable
    abstract_args: Callable[[], Tuple[tuple, dict]]
    module: str
    oracle: Optional[str] = None          # dotted path of the host oracle
    carry: Tuple[int, ...] = ()           # round-carried positional args
    donate: Tuple[int, ...] = ()          # args the program donates
    budget_bytes: int = DEFAULT_BUDGET_BYTES
    allowed_dtypes: frozenset = DEFAULT_ALLOWED_DTYPES
    allow_callbacks: bool = False
    # (src, dst) convert_element_type pairs this program performs on
    # purpose (e.g. the bf16 wire cast from common/precision.py). The
    # convert-churn lint skips A->B->A round-trips whose both legs are
    # sanctioned; everything else still fails.
    sanctioned_casts: frozenset = frozenset()

    def build_args(self) -> Tuple[tuple, dict]:
        return self.abstract_args()


_REGISTRY: Dict[str, ProgramSpec] = {}
_LOADED = False


def _register(spec: ProgramSpec) -> None:
    prev = _REGISTRY.get(spec.name)
    if prev is not None and prev.module != spec.module:
        raise ValueError(
            f"program {spec.name!r} registered twice "
            f"({prev.module} and {spec.module})")
    _REGISTRY[spec.name] = spec


def register_program(name: str, *, abstract_args, oracle=None, carry=(),
                     donate=(), budget_bytes=DEFAULT_BUDGET_BYTES,
                     allowed_dtypes=DEFAULT_ALLOWED_DTYPES,
                     allow_callbacks=False, sanctioned_casts=frozenset()):
    """Decorator: record ``fn`` as the traceable program ``name``."""

    def wrap(fn):
        _register(ProgramSpec(
            name=name, fn=fn, abstract_args=abstract_args,
            module=getattr(fn, "__module__", "<runtime>"), oracle=oracle,
            carry=tuple(carry), donate=tuple(donate),
            budget_bytes=budget_bytes,
            allowed_dtypes=frozenset(allowed_dtypes),
            allow_callbacks=allow_callbacks,
            sanctioned_casts=frozenset(sanctioned_casts)))
        return fn

    return wrap


def register_runtime(name: str, fn: Callable, *, abstract_args, module: str,
                     **kw) -> None:
    """Manifest entry point for closures built at runtime."""
    spec = ProgramSpec(
        name=name, fn=fn, abstract_args=abstract_args, module=module,
        oracle=kw.get("oracle"), carry=tuple(kw.get("carry", ())),
        donate=tuple(kw.get("donate", ())),
        budget_bytes=kw.get("budget_bytes", DEFAULT_BUDGET_BYTES),
        allowed_dtypes=frozenset(
            kw.get("allowed_dtypes", DEFAULT_ALLOWED_DTYPES)),
        allow_callbacks=kw.get("allow_callbacks", False),
        sanctioned_casts=frozenset(kw.get("sanctioned_casts", ())))
    _register(spec)


def load_all() -> Dict[str, ProgramSpec]:
    """Import every program module (running the decorators + manifest)
    and return the full registry. Idempotent."""
    global _LOADED
    if not _LOADED:
        for mod in PROGRAM_MODULES:
            importlib.import_module(mod)
        _LOADED = True
    return dict(_REGISTRY)


def iter_programs() -> List[ProgramSpec]:
    return [load_all()[k] for k in sorted(load_all())]


def get_program(name: str) -> ProgramSpec:
    reg = load_all()
    if name not in reg:
        raise KeyError(f"unknown program {name!r}; registered: {sorted(reg)}")
    return reg[name]


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def trace(spec: ProgramSpec):
    """ClosedJaxpr of the program over its abstract args (no execution)."""
    import jax
    args, kwargs = spec.build_args()
    return jax.make_jaxpr(functools.partial(spec.fn, **kwargs))(*args)


def lowered_text(spec: ProgramSpec) -> Optional[str]:
    """StableHLO text of the program's own jit (None when the registered
    callable is not a jit wrapper). Used by the donation lint: donated
    inputs carry a ``tf.aliasing_output`` attribute in the lowering."""
    lower = getattr(spec.fn, "lower", None)
    if lower is None:
        return None
    args, kwargs = spec.build_args()
    try:
        return lower(*args, **kwargs).as_text()
    except Exception:
        return None


def resolve_oracle(path: str) -> Any:
    """Import the dotted ``module.attr[.attr...]`` oracle path."""
    parts = path.split(".")
    for split in range(len(parts) - 1, 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:split]))
        except ImportError:
            continue
        try:
            for attr in parts[split:]:
                obj = getattr(obj, attr)
        except AttributeError:
            break
        return obj
    raise ImportError(f"oracle path {path!r} does not resolve")


def coverage() -> Dict[str, Any]:
    """Registry coverage for the BENCH_*.json metadata: how many of the
    registered programs trace cleanly right now. A program silently
    dropping out of analysis shows up as traced < registered."""
    traced, failed = [], []
    for spec in iter_programs():
        try:
            trace(spec)
            traced.append(spec.name)
        except Exception as e:                      # noqa: BLE001
            failed.append({"name": spec.name, "error": repr(e)[:200]})
    out = {"programs_registered": len(traced) + len(failed),
           "programs_traced": len(traced), "traced": traced}
    if failed:
        out["failed"] = failed
    return out
