"""Lint CLI: trace every registered program, run all passes, report.

    PYTHONPATH=src python -m repro.analysis.lint [--program NAME] [--json]

Exit status 0 iff every finding is covered by the baseline
(``analysis/baseline.json``). The baseline is a suppression list, not a
bug tracker: every entry carries a ``reason`` saying why the finding is
accepted, and entries that no longer match anything are reported as stale
(so fixes retire their suppressions).

Baseline entry shape::

    {"code": "dead-code", "program": "federated.stacked_eval",
     "match": "substring of the finding message (optional)",
     "reason": "why this is accepted"}

``--program NAME`` restricts to one program's jaxpr lints (skipping the
repo-wide convention passes); ``--json`` emits the machine-readable
report the CI wrapper and the benchmarks' coverage metadata consume.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple

from repro.analysis import conventions, lints, registry
from repro.analysis.lints import Finding

BASELINE_PATH = Path(__file__).with_name("baseline.json")


def load_baseline(path: Path) -> List[Dict]:
    if not path.exists():
        return []
    return json.loads(path.read_text())["suppressions"]


def partition_findings(findings: List[Finding], suppressions: List[Dict]
                       ) -> Tuple[List[Finding], List[Finding], List[Dict]]:
    """-> (new findings, baselined findings, stale suppressions)."""
    hit = [False] * len(suppressions)
    new, base = [], []
    for f in findings:
        matched = False
        for i, s in enumerate(suppressions):
            if (s["code"] == f.code and s["program"] == f.program
                    and s.get("match", "") in f.message):
                hit[i] = matched = True
        (base if matched else new).append(f)
    stale = [s for i, s in enumerate(suppressions) if not hit[i]]
    return new, base, stale


def run(program: str = None) -> Dict:
    """Trace + lint -> the full report dict (the CLI's --json payload)."""
    specs = registry.iter_programs()
    if program is not None:
        specs = [registry.get_program(program)]
    findings: List[Finding] = []
    programs: Dict[str, Dict] = {}
    for spec in specs:
        try:
            closed = registry.trace(spec)
        except Exception as e:                              # noqa: BLE001
            findings.append(Finding(
                "untraceable", spec.name,
                f"abstract trace failed: {e!r:.200}"))
            programs[spec.name] = {"traced": False}
            continue
        fs, stats = lints.run_jaxpr_lints(closed, spec)
        findings.extend(fs)
        programs[spec.name] = {"traced": True, **stats}
    if program is None:
        findings.extend(conventions.run_convention_lints(
            conventions.repo_root(), specs))
    return {"programs": programs, "findings": findings}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis.lint")
    ap.add_argument("--program", default=None,
                    help="lint one registered program (jaxpr passes only)")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--baseline", type=Path, default=BASELINE_PATH)
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding (ignore suppressions)")
    args = ap.parse_args(argv)

    report = run(args.program)
    suppressions = [] if args.no_baseline else load_baseline(args.baseline)
    new, base, stale = partition_findings(report["findings"], suppressions)

    traced = [n for n, p in report["programs"].items() if p["traced"]]
    if args.as_json:
        print(json.dumps({
            "programs_registered": len(report["programs"]),
            "programs_traced": len(traced),
            "programs": report["programs"],
            "findings": [f.as_dict() for f in new],
            "baselined": [f.as_dict() for f in base],
            "stale_suppressions": stale,
        }, indent=2))
    else:
        print(f"traced {len(traced)}/{len(report['programs'])} "
              f"registered programs")
        for name in sorted(report["programs"]):
            p = report["programs"][name]
            if p["traced"]:
                print(f"  {name:45s} {p['eqns']:5d} eqns  "
                      f"peak~{p['peak_bytes'] / 1e6:8.1f} MB")
            else:
                print(f"  {name:45s} TRACE FAILED")
        for f in new:
            print(f"FINDING [{f.code}] {f.program}: {f.message}")
        for s in stale:
            print(f"STALE SUPPRESSION [{s['code']}] {s['program']}: "
                  f"{s.get('reason', '')}")
        print(f"{len(new)} finding(s), {len(base)} baselined, "
              f"{len(stale)} stale suppression(s)")
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
