"""AST-level convention lints (no imports of the linted code).

Repo conventions enforced here (see ROADMAP "Standing measured
constraints" — every fast path keeps a host oracle):

  * ``kernel-no-ref`` / ``kernel-ref-unwired`` / ``kernel-no-parity-test``
    / ``kernel-module-unwired`` — every kernel module under
    ``src/repro/kernels/`` must have a ``ref.py`` oracle
    (``<dispatcher>_ref``), an ``ops.py`` dispatcher entry that actually
    routes ``backend="ref"`` to it, and a parity test under ``tests/``.
  * ``fast-path-no-oracle`` / ``fast-path-oracle-unresolved`` — every
    registered program (the ``engine="stacked"`` / ``eval_backend=
    "device"`` fast paths) must name its host oracle, and the dotted path
    must resolve.
  * ``unused-import`` — pyflakes-F401-style unused imports in ``src/``
    and ``tests/`` (``__init__.py`` re-export modules are exempt).
  * ``dead-module`` / ``seed-module`` — modules under ``repro.configs``
    and ``repro.models`` that no registered program reaches through the
    import graph: ``dead-module`` when no test reaches them either
    (delete), ``seed-module`` when only LM-side tests keep them alive
    (they stay only with an explicit allowlist entry in ``baseline.json``
    stating why).

All functions take the repo root explicitly so the analyzer's own tests
can point them at synthetic known-bad trees.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.lints import Finding
from repro.analysis.registry import ProgramSpec, resolve_oracle

REPO = "<repo>"    # program slot for repo-level (non-program) findings


def repo_root() -> Path:
    return Path(__file__).resolve().parents[3]


def _parse(path: Path) -> Optional[ast.AST]:
    try:
        return ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return None


# ---------------------------------------------------------------------------
# kernel pairing: module <-> ref oracle <-> ops dispatcher <-> parity test
# ---------------------------------------------------------------------------


def lint_kernel_conventions(root: Path) -> List[Finding]:
    kdir = root / "src" / "repro" / "kernels"
    tests_dir = root / "tests"
    out: List[Finding] = []
    ops_path, ref_path = kdir / "ops.py", kdir / "ref.py"
    if not ops_path.exists() or not ref_path.exists():
        return [Finding("kernel-no-ref", REPO,
                        f"kernels package at {kdir} lacks ops.py/ref.py")]
    ops_tree = _parse(ops_path)
    ref_defs = {n.name for n in ast.walk(_parse(ref_path))
                if isinstance(n, ast.FunctionDef)}
    test_text = "\n".join(p.read_text()
                          for p in sorted(tests_dir.glob("test_*.py")))

    dispatchers: List[ast.FunctionDef] = []
    ops_imported_modules: Set[str] = set()
    for node in ast.walk(ops_tree):
        if isinstance(node, ast.FunctionDef):
            if node.name.startswith("_"):      # helpers are not dispatchers
                continue
            args = node.args.args + node.args.kwonlyargs
            if any(a.arg == "backend" for a in args):
                dispatchers.append(node)
        elif isinstance(node, ast.ImportFrom) and node.module:
            ops_imported_modules.add(node.module)

    for fn in dispatchers:
        ref_name = f"{fn.name}_ref"
        if ref_name not in ref_defs:
            out.append(Finding(
                "kernel-no-ref", REPO,
                f"ops dispatcher `{fn.name}` has no `{ref_name}` oracle "
                f"in kernels/ref.py"))
        elif not any(isinstance(n, ast.Attribute) and n.attr == ref_name
                     for n in ast.walk(fn)):
            out.append(Finding(
                "kernel-ref-unwired", REPO,
                f"ops dispatcher `{fn.name}` never routes to "
                f"`REF.{ref_name}` (backend=\"ref\" path missing)"))
        if not re.search(rf"\b{re.escape(fn.name)}\b", test_text):
            out.append(Finding(
                "kernel-no-parity-test", REPO,
                f"no test under tests/ exercises kernel dispatcher "
                f"`{fn.name}` (ref-vs-kernel parity unguarded)"))

    for mod in sorted(kdir.glob("*.py")):
        stem = mod.stem
        if stem in ("__init__", "ops", "ref"):
            continue
        if f"repro.kernels.{stem}" not in ops_imported_modules:
            out.append(Finding(
                "kernel-module-unwired", REPO,
                f"kernel module kernels/{stem}.py has no ops.py "
                f"dispatcher entry"))
    return out


# ---------------------------------------------------------------------------
# fast paths name their host oracle
# ---------------------------------------------------------------------------


def lint_fast_path_oracles(specs: Iterable[ProgramSpec]) -> List[Finding]:
    out: List[Finding] = []
    for spec in specs:
        if not spec.oracle:
            out.append(Finding(
                "fast-path-no-oracle", spec.name,
                "registered fast path declares no host oracle "
                "(oracle=... on register_program)"))
            continue
        try:
            resolve_oracle(spec.oracle)
        except ImportError:
            out.append(Finding(
                "fast-path-oracle-unresolved", spec.name,
                f"declared oracle {spec.oracle!r} does not resolve"))
    return out


# ---------------------------------------------------------------------------
# unused imports (pyflakes F401, the AST way)
# ---------------------------------------------------------------------------


def _unused_imports_in_file(path: Path) -> List[Finding]:
    tree = _parse(path)
    if tree is None:
        return []
    bound: List = []         # (name, lineno, display)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = a.asname or a.name.split(".")[0]
                bound.append((name, node.lineno, a.name))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                name = a.asname or a.name
                bound.append((name, node.lineno,
                              f"{node.module or '.'}.{a.name}"))
    if not bound:
        return []
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            used.add(node.attr)
    # names re-exported via __all__ count as used
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets)):
            for c in ast.walk(node.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    used.add(c.value)
    return [Finding("unused-import", REPO,
                    f"{path}:{lineno}: `{display}` imported as `{name}` "
                    f"but never used")
            for name, lineno, display in bound if name not in used]


def lint_unused_imports(root: Path,
                        subdirs: Iterable[str] = ("src", "tests")
                        ) -> List[Finding]:
    out: List[Finding] = []
    for sub in subdirs:
        for path in sorted((root / sub).rglob("*.py")):
            if path.name == "__init__.py":      # re-export modules
                continue
            out.extend(_unused_imports_in_file(path))
    return out


# ---------------------------------------------------------------------------
# dead / seed modules under configs/ and models/
# ---------------------------------------------------------------------------


def _module_name(src: Path, path: Path) -> str:
    rel = path.relative_to(src).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _repro_imports(tree: ast.AST, modules: Set[str]) -> Set[str]:
    """Module names under ``repro`` imported anywhere in the tree."""
    out: Set[str] = set()

    def add(name: str) -> None:
        if name in modules:
            out.add(name)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                add(a.name)
        elif isinstance(node, ast.ImportFrom) and node.module:
            add(node.module)
            for a in node.names:
                add(f"{node.module}.{a.name}")   # from repro.x import submod
    return out


def build_import_graph(root: Path) -> Dict[str, Set[str]]:
    """repro-module -> set of repro-modules it imports (package inits are
    edges too: importing ``repro.configs`` pulls every config module)."""
    src = root / "src"
    files = {p: _module_name(src, p)
             for p in sorted((src / "repro").rglob("*.py"))}
    modules = set(files.values())
    graph: Dict[str, Set[str]] = {m: set() for m in modules}
    for path, mod in files.items():
        tree = _parse(path)
        if tree is None:
            continue
        graph[mod] |= _repro_imports(tree, modules)
    return graph


def _reach(graph: Dict[str, Set[str]], roots: Iterable[str]) -> Set[str]:
    seen: Set[str] = set()
    stack = [r for r in roots if r in graph]
    while stack:
        m = stack.pop()
        if m in seen:
            continue
        seen.add(m)
        stack.extend(graph.get(m, ()))
        # importing a submodule imports its package __init__ too
        while "." in m:
            m = m.rsplit(".", 1)[0]
            if m in graph and m not in seen:
                seen.add(m)
                stack.extend(graph.get(m, ()))
    return seen


def _strict_graph(graph: Dict[str, Set[str]],
                  scopes: Iterable[str]) -> Dict[str, Set[str]]:
    """The import graph with scope-package ``__init__`` fan-out removed:
    a scope package's init re-exporting every submodule (the registry
    pattern in ``repro.configs``) no longer marks them all reachable —
    a scoped module counts as alive only when some module imports it BY
    NAME. Reachability for tests keeps the full graph (a parametrized
    smoke over the registry is a real consumer); registry reachability
    uses this one, so registry-dead scoped modules surface as
    ``seed-module`` findings that need an explicit allowlist reason."""
    strict = {m: set(es) for m, es in graph.items()}
    for s in scopes:
        if s in strict:
            strict[s] = {e for e in strict[s] if not e.startswith(s + ".")}
    return strict


def lint_dead_modules(root: Path, specs: Iterable[ProgramSpec],
                      scopes: Iterable[str] = ("repro.configs",
                                               "repro.models")
                      ) -> List[Finding]:
    graph = build_import_graph(root)
    modules = set(graph)
    test_roots: Set[str] = set()
    for p in sorted((root / "tests").glob("*.py")):
        tree = _parse(p)
        if tree is not None:
            test_roots |= _repro_imports(tree, modules)
    registry_roots = {s.module for s in specs if s.module in modules}
    from_registry = _reach(_strict_graph(graph, scopes), registry_roots)
    from_tests = _reach(graph, test_roots)
    out: List[Finding] = []
    for mod in sorted(modules):
        if not any(mod == s or mod.startswith(s + ".") for s in scopes):
            continue
        if mod in from_registry:
            continue
        if mod in from_tests:
            out.append(Finding(
                "seed-module", REPO,
                f"{mod} is reached by tests but by NO registered program "
                f"(seed module: keep only with an allowlist entry)"))
        else:
            out.append(Finding(
                "dead-module", REPO,
                f"{mod} is reached by neither a registered program nor a "
                f"test (delete, or allowlist with a reason)"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_convention_lints(root: Path,
                         specs: Iterable[ProgramSpec]) -> List[Finding]:
    specs = list(specs)
    out: List[Finding] = []
    out += lint_kernel_conventions(root)
    out += lint_fast_path_oracles(specs)
    out += lint_unused_imports(root)
    out += lint_dead_modules(root, specs)
    return out
