"""Jaxpr lint passes: the repo's measured invariants, checked at trace time.

Each pass walks a ``ClosedJaxpr`` (from ``registry.trace`` — abstract
tracing, no data, no execution) and returns ``Finding`` records:

  * ``dtype-widen``     — an aval dtype outside the program's declared set
    (default: the wire/compute dtypes bf16/int8/f32 plus index/mask types).
    f64 / i64 / complex creep fails here before it ever doubles a buffer.
  * ``convert-churn``   — an A→B→A ``convert_element_type`` round-trip
    (a value converted and converted straight back: wasted casts that
    usually mark an accidental promotion being papered over).
  * ``host-callback``   — ``pure_callback``/``io_callback``/debug prints
    in the program; fatal inside ``scan``/``while`` bodies, where one
    callback per iteration serializes the whole loop on host round-trips.
  * ``host-transfer``   — ``device_put`` inside a loop body.
  * ``undonated-carry`` — a declared round-carried input the program does
    not donate: at C ≫ 1000 the stacked (C, ...) state doubles in memory
    every round. Checked against the declaration AND the traced pjit's
    ``donated_invars``.
  * ``dead-code``       — equations whose outputs never reach a program
    output (XLA DCEs them, but they are trace/compile churn and usually
    mark an API returning data nobody consumes).
  * ``peak-bytes``      — a static peak-live-intermediate-bytes estimate
    (linear-scan liveness over the jaxpr, dtype widths from
    ``sharding.analysis``) exceeding the program's declared budget.

``run_jaxpr_lints`` runs every pass and also returns per-program stats
(peak-bytes estimate, eqn count) for the CLI report.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

from jax import core

from repro.analysis.registry import ProgramSpec
from repro.sharding.analysis import aval_bytes

_LOOP_PRIMS = ("scan", "while")
_TRANSFER_PRIMS = ("device_put",)


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str            # lint pass id, e.g. "dtype-widen"
    program: str         # registered program name, or "<repo>" for AST lints
    message: str

    def as_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(eqn) -> Iterator[core.Jaxpr]:
    for v in eqn.params.values():
        if isinstance(v, core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, core.ClosedJaxpr):
                    yield item.jaxpr
                elif isinstance(item, core.Jaxpr):
                    yield item


def iter_eqns(jaxpr: core.Jaxpr, path: Tuple[str, ...] = (),
              in_loop: bool = False):
    """Yield (eqn, path, in_loop) over the jaxpr and every sub-jaxpr."""
    for eqn in jaxpr.eqns:
        yield eqn, path, in_loop
        name = eqn.primitive.name
        inner_loop = in_loop or name in _LOOP_PRIMS
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, path + (name,), inner_loop)


def iter_jaxprs(jaxpr: core.Jaxpr) -> Iterator[core.Jaxpr]:
    yield jaxpr
    for eqn in jaxpr.eqns:
        for sub in _sub_jaxprs(eqn):
            yield from iter_jaxprs(sub)


def _nbytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return aval_bytes(shape, dtype)


# ---------------------------------------------------------------------------
# passes
# ---------------------------------------------------------------------------


def lint_dtypes(closed: core.ClosedJaxpr, spec: ProgramSpec) -> List[Finding]:
    """Flag any aval dtype outside the program's allowed set."""
    seen: Dict[str, str] = {}
    top = closed.jaxpr
    for v in list(top.invars) + list(top.constvars):
        dt = getattr(v.aval, "dtype", None)
        if dt is not None and dt.name not in spec.allowed_dtypes:
            seen.setdefault(dt.name, f"program input {v.aval.str_short()}")
    for eqn, path, _ in iter_eqns(top):
        for v in eqn.outvars:
            dt = getattr(v.aval, "dtype", None)
            if dt is not None and dt.name not in spec.allowed_dtypes:
                where = "/".join(path) or "top"
                seen.setdefault(
                    dt.name,
                    f"`{eqn.primitive.name}` -> {v.aval.str_short()} "
                    f"at {where}")
    return [Finding("dtype-widen", spec.name,
                    f"dtype {name} outside allowed "
                    f"{sorted(spec.allowed_dtypes)}: first at {ctx}")
            for name, ctx in sorted(seen.items())]


def lint_convert_churn(closed: core.ClosedJaxpr,
                       spec: ProgramSpec) -> List[Finding]:
    """Flag A→B→A convert_element_type round-trips (per jaxpr level).

    A round-trip whose BOTH legs are in ``spec.sanctioned_casts`` —
    e.g. the engine's f32→bf16 wire cast and the server's bf16→f32
    upcast from ``common/precision.py`` — is a declared precision
    boundary, not churn, and is skipped."""
    out: List[Finding] = []
    for jaxpr in iter_jaxprs(closed.jaxpr):
        produced = {}
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            src = eqn.invars[0]
            dst = eqn.outvars[0]
            if isinstance(src, core.Var) and src in produced:
                orig = produced[src]
                mid = getattr(src.aval, "dtype", None)
                if getattr(dst.aval, "dtype", None) == orig:
                    mid_name = getattr(mid, "name", "?")
                    legs = {(orig.name, mid_name), (mid_name, orig.name)}
                    if not legs <= spec.sanctioned_casts:
                        out.append(Finding(
                            "convert-churn", spec.name,
                            f"{orig.name} -> {mid_name} -> "
                            f"{orig.name} convert round-trip"))
            if isinstance(src, (core.Var, core.Literal)):
                dt = getattr(src.aval, "dtype", None)
                if dt is not None:
                    produced[dst] = dt
    return out


def lint_host_transfers(closed: core.ClosedJaxpr,
                        spec: ProgramSpec) -> List[Finding]:
    """Flag callbacks (always) and device_put (inside loop bodies)."""
    out: List[Finding] = []
    for eqn, path, in_loop in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        where = "/".join(path) or "top"
        if "callback" in name or name in ("infeed", "outfeed"):
            if spec.allow_callbacks:
                continue
            loop_note = (" INSIDE a loop body (one host round-trip per "
                         "iteration)" if in_loop else "")
            out.append(Finding(
                "host-callback", spec.name,
                f"host callback `{name}` at {where}{loop_note}"))
        elif name in _TRANSFER_PRIMS and in_loop:
            out.append(Finding(
                "host-transfer", spec.name,
                f"`{name}` inside a loop body at {where}"))
    return out


def lint_donation(spec: ProgramSpec,
                  closed: Optional[core.ClosedJaxpr] = None) -> List[Finding]:
    """Round-carried state must be donated, by declaration and in fact."""
    out = [Finding("undonated-carry", spec.name,
                   f"round-carried arg {i} is not in donate={spec.donate}: "
                   f"the old buffer stays live an extra round "
                   f"(memory doubles at C >> 1000)")
           for i in spec.carry if i not in spec.donate]
    if spec.donate and closed is not None:
        # the traced pjit records donation per flattened invar — if the
        # registered callable is the production jit, this is ground truth
        pjits = [e for e in closed.jaxpr.eqns if e.primitive.name == "pjit"]
        if len(pjits) == 1 and not any(pjits[0].params.get("donated_invars",
                                                           ())):
            out.append(Finding(
                "undonated-carry", spec.name,
                f"declares donate={spec.donate} but the traced jit has no "
                f"donated invars (donate_argnums missing on the jit?)"))
    return out


def _dead_eqns(jaxpr: core.Jaxpr):
    """Equations whose outputs never (transitively) reach this jaxpr's
    outputs. Effectful eqns are always live."""
    live = {v for v in jaxpr.outvars if isinstance(v, core.Var)}
    dead = []
    for eqn in reversed(jaxpr.eqns):
        outs = [v for v in eqn.outvars if not isinstance(v, core.DropVar)]
        if eqn.effects or any(v in live for v in outs):
            for v in eqn.invars:
                if isinstance(v, core.Var):
                    live.add(v)
        else:
            dead.append(eqn)
    return dead


def lint_dead_code(closed: core.ClosedJaxpr,
                   spec: ProgramSpec) -> List[Finding]:
    out: List[Finding] = []
    for jaxpr in iter_jaxprs(closed.jaxpr):
        dead = _dead_eqns(jaxpr)
        if dead:
            prims = sorted({e.primitive.name for e in dead})
            out.append(Finding(
                "dead-code", spec.name,
                f"{len(dead)} equation(s) never reach an output "
                f"(prims: {', '.join(prims[:6])})"))
    return out


def peak_bytes_estimate(jaxpr: core.Jaxpr) -> int:
    """Static peak live-intermediate bytes: linear-scan liveness over the
    eqns (inputs + consts live throughout their use span, outputs pinned),
    plus the recursive peak of whichever sub-jaxpr is on the stack."""
    n = len(jaxpr.eqns)
    last_use: Dict[core.Var, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if isinstance(v, core.Var):
                last_use[v] = i
    for v in jaxpr.outvars:
        if isinstance(v, core.Var):
            last_use[v] = n
    alive: Dict[core.Var, int] = {}
    for v in list(jaxpr.invars) + list(jaxpr.constvars):
        alive[v] = _nbytes(v.aval)
    peak = sum(alive.values())
    for i, eqn in enumerate(jaxpr.eqns):
        # a sub-jaxpr's inputs are bound to values already counted in the
        # outer frame, so only its NET peak (intermediates beyond its own
        # inputs) stacks on top
        sub_peak = max((max(0, peak_bytes_estimate(s)
                            - sum(_nbytes(v.aval)
                                  for v in list(s.invars) + list(s.constvars)))
                        for s in _sub_jaxprs(eqn)),
                       default=0)
        for v in eqn.outvars:
            if not isinstance(v, core.DropVar):
                alive[v] = _nbytes(v.aval)
        peak = max(peak, sum(alive.values()) + sub_peak)
        for v in [v for v, last in last_use.items() if last == i]:
            alive.pop(v, None)
    return peak


def lint_peak_bytes(closed: core.ClosedJaxpr, spec: ProgramSpec,
                    peak: Optional[int] = None) -> List[Finding]:
    if peak is None:
        peak = peak_bytes_estimate(closed.jaxpr)
    if peak > spec.budget_bytes:
        return [Finding(
            "peak-bytes", spec.name,
            f"estimated peak intermediates {peak / 1e6:.1f} MB exceed the "
            f"declared budget {spec.budget_bytes / 1e6:.1f} MB")]
    return []


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_jaxpr_lints(closed: core.ClosedJaxpr, spec: ProgramSpec
                    ) -> Tuple[List[Finding], Dict[str, int]]:
    """All passes over one traced program -> (findings, stats)."""
    peak = peak_bytes_estimate(closed.jaxpr)
    findings: List[Finding] = []
    findings += lint_dtypes(closed, spec)
    findings += lint_convert_churn(closed, spec)
    findings += lint_host_transfers(closed, spec)
    findings += lint_donation(spec, closed)
    findings += lint_dead_code(closed, spec)
    findings += lint_peak_bytes(closed, spec, peak)
    n_eqns = sum(len(j.eqns) for j in iter_jaxprs(closed.jaxpr))
    return findings, {"peak_bytes": peak, "eqns": n_eqns}
