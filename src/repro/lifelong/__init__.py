from repro.lifelong.strategies import EWC, ICaRL, MAS, STL
