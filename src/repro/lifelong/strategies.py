"""Lifelong-learning baselines (paper Table II, local-only methods):

  * EWC   [Kirkpatrick+ 17]: diagonal-Fisher penalty on parameter movement.
  * MAS   [Aljundi+ 18]: importance = |∂||f(x)||²/∂θ| accumulated, same form.
  * iCaRL [Rebuffi+ 17]: raw-image exemplar rehearsal, nearest-mean selection.

All train locally with no server exchange (comm = 0 / NaN in the paper).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_bytes, tree_zeros_like
from repro.core import edge_model as EM
from repro.federated.base import Strategy


class STL(Strategy):
    name = "stl"
    # pure local minibatch training — batches cleanly over clients
    supports_stacked = True


class EWC(Strategy):
    name = "ewc"

    def __init__(self, cfg, *, lam=0.1, **kw):
        super().__init__(cfg, **kw)
        self.lam = lam

    def init_client(self, key):
        st = super().init_client(key)
        st.extras["reg_fisher"] = tree_zeros_like(st.theta)
        st.extras["reg_anchor"] = jax.tree.map(jnp.array, st.theta)
        return st

    def regularizer(self, trainable, extras):
        pen = sum(
            jnp.sum(f * jnp.square(t - a))
            for f, t, a in zip(jax.tree.leaves(extras["reg_fisher"]),
                               jax.tree.leaves(trainable),
                               jax.tree.leaves(extras["reg_anchor"])))
        return 0.5 * self.lam * pen

    def _importance(self, theta, protos, labels):
        """Diagonal Fisher: E[grad log p(y|x)^2], estimated over chunks of 8
        (NOT per-sample: the BN-style standardisation has an undefined
        gradient at batch size 1 — zero variance)."""
        n = (len(protos) // 8) * 8
        px = protos[:n].reshape(-1, 8, protos.shape[-1])
        py = labels[:n].reshape(-1, 8)
        def nll(th, x, y):
            return EM.ce_loss(th, x, y)
        g = jax.vmap(lambda x, y: jax.grad(nll)(theta, x, y))(px, py)
        return jax.tree.map(lambda gg: jnp.mean(jnp.square(gg), 0), g)

    def local_train(self, client, state, protos, labels, rnd, *,
                    consolidate=False, **_):
        state, _ = self._run_epochs(state, protos, labels)
        if consolidate:
            # consolidate at TASK end only (paper/EWC semantics): decayed
            # accumulation keeps the penalty bounded over many tasks
            n = min(len(protos), 64)
            f_new = self._importance(state.theta, jnp.asarray(protos[:n]),
                                     jnp.asarray(labels[:n]))
            state.extras["reg_fisher"] = jax.tree.map(
                lambda old, new: 0.5 * old + new,
                state.extras["reg_fisher"], f_new)
            state.extras["reg_anchor"] = state.theta
        return state, None

    def storage_bytes(self, state):
        return (tree_bytes(state.theta)
                + tree_bytes(state.extras["reg_fisher"])
                + tree_bytes(state.extras["reg_anchor"]))


class MAS(EWC):
    name = "mas"

    def _importance(self, theta, protos, labels):
        """MAS: sensitivity of the squared output norm (chunked, see EWC)."""
        n = (len(protos) // 8) * 8
        px = protos[:n].reshape(-1, 8, protos.shape[-1])
        def out_norm(th, x):
            feats, logits = EM.adaptive_forward(th, x)
            return jnp.mean(jnp.sum(jnp.square(logits), -1))
        g = jax.vmap(lambda x: jax.grad(out_norm)(theta, x))(px)
        return jax.tree.map(lambda gg: jnp.mean(jnp.abs(gg), 0), g)


class ICaRL(Strategy):
    """Raw-image exemplar rehearsal (needs the extraction layers to re-encode
    stored images every round — contrast with FedSTIL's prototype memory)."""

    name = "icarl"

    def __init__(self, cfg, *, memory_size=2000, per_identity=8,
                 extractor=None, **kw):
        super().__init__(cfg, **kw)
        self.memory_size = memory_size
        self.per_identity = per_identity
        self.extractor = extractor     # (g_params, raw images) -> prototypes

    def init_client(self, key):
        st = super().init_client(key)
        st.extras["mem_x"] = None      # raw images
        st.extras["mem_y"] = None
        return st

    def local_train(self, client, state, protos, labels, rnd,
                    raw_images=None, g_params=None, **_):
        rehearsal = None
        if state.extras["mem_x"] is not None and self.extractor is not None:
            mem_protos = np.asarray(self.extractor(g_params, state.extras["mem_x"]))
            rehearsal = (mem_protos, state.extras["mem_y"])
        state, _ = self._run_epochs(state, protos, labels, rehearsal)

        # nearest-mean exemplar selection on raw images
        if raw_images is not None:
            feats, _ = EM.adaptive_forward(state.theta, jnp.asarray(protos))
            feats = np.asarray(feats)
            keep = []
            for ident in np.unique(labels):
                idx = np.nonzero(labels == ident)[0]
                center = feats[idx].mean(0)
                d = np.linalg.norm(feats[idx] - center, axis=1)
                keep.extend(idx[np.argsort(d)[: self.per_identity]].tolist())
            keep = np.asarray(keep, np.int64)
            nx, ny = raw_images[keep], labels[keep]
            if state.extras["mem_x"] is None:
                state.extras["mem_x"], state.extras["mem_y"] = nx, ny
            else:
                state.extras["mem_x"] = np.concatenate([state.extras["mem_x"], nx])
                state.extras["mem_y"] = np.concatenate([state.extras["mem_y"], ny])
            if len(state.extras["mem_x"]) > self.memory_size:
                sel = self.rng.choice(len(state.extras["mem_x"]),
                                      self.memory_size, replace=False)
                state.extras["mem_x"] = state.extras["mem_x"][sel]
                state.extras["mem_y"] = state.extras["mem_y"][sel]
        return state, None

    def storage_bytes(self, state):
        extra = 0
        if state.extras["mem_x"] is not None:
            extra = state.extras["mem_x"].nbytes + state.extras["mem_y"].nbytes
        return tree_bytes(state.theta) + extra
