"""Span tracer with explicit device-sync boundaries.

The engines are instrumented with the module-level helpers::

    from repro.obs import trace as obs

    with obs.span("server.relevance", cat="stage", round=rnd) as sp:
        out = relevance(...)
        sp.sync(out)          # block_until_ready: honest span end

    obs.metric("server.relevance", {"staleness": stale}, round=rnd)

and a run activates a tracer around its loop::

    tracer = obs.Tracer("run.jsonl")
    with obs.active(tracer):
        run_simulation(...)
    tracer.close()            # flush JSONL (active() does NOT close)

When no tracer is active the helpers dispatch to the null tracer: the
span context manager is a shared constant object, ``sp.sync(x)`` returns
``x`` WITHOUT blocking (async dispatch is preserved — tracing off must
not add device-sync points), and ``metric()`` returns before touching
its value dict. That is the off-by-default-cheap contract the server
bench gates at <2% of stacked round wall-time.

Timing semantics with a tracer active: a span records host wall time
(``perf_counter``) from ``__enter__`` to ``__exit__``; calling
``sp.sync(arrays)`` inside the body blocks until the device work backing
``arrays`` is done, so the recorded duration covers execution, not just
dispatch. Event schema (one JSON object per line):

    {"kind": "span",   "name": ..., "t0": s, "dur": s, ...attrs}
    {"kind": "metric", "name": ..., "values": {...}, "t0": s, ...attrs}
    {"kind": "meta",   ...}
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional

import numpy as np


class RunLog:
    """Append-only JSONL sink for telemetry events.

    Events are buffered in memory and written on ``flush()``/``close()``
    — never inside the hot loop, so an active tracer costs list appends,
    not I/O. ``RunLog.read(path)`` parses a file back to event dicts.
    """

    def __init__(self, path):
        self.path = Path(path)
        self._buf: List[Dict[str, Any]] = []

    def append(self, event: Dict[str, Any]) -> None:
        self._buf.append(event)

    def flush(self) -> None:
        if not self._buf:
            return
        with self.path.open("a") as f:
            for e in self._buf:
                f.write(json.dumps(e) + "\n")
        self._buf.clear()

    def close(self) -> None:
        self.flush()

    @staticmethod
    def read(path) -> List[Dict[str, Any]]:
        events = []
        with Path(path).open() as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
        return events


class _Span:
    """One live span (reused API surface with ``_NULL_SPAN``)."""

    __slots__ = ("tracer", "name", "attrs", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0

    def sync(self, value):
        """Block until the device work backing ``value`` is done; returns
        ``value``. The honest end-of-span device boundary."""
        import jax
        return jax.block_until_ready(value)

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self.tracer._emit({"kind": "span", "name": self.name,
                           "t0": self.t0, "dur": t1 - self.t0, **self.attrs})
        return False


class _NullSpan:
    """The tracing-off span: no timestamps, no blocking, one shared
    instance. ``sync`` is identity — async dispatch stays async."""

    __slots__ = ()

    def sync(self, value):
        return value

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Dispatch target when tracing is off. Every hook is a near-no-op."""

    active = False

    def span(self, name, **attrs):
        return _NULL_SPAN

    def metric(self, name, values=None, **attrs):
        return None

    def meta(self, **fields):
        return None

    def close(self):
        return None


class Tracer(NullTracer):
    """In-memory span/metric recorder with an optional JSONL sink.

    ``path=None`` keeps everything in ``self.events`` (benches read it
    directly); with a path, ``close()`` flushes the run to JSONL. The
    epoch (first event's perf_counter) is recorded as a meta event so
    reports can print relative times.
    """

    active = True

    def __init__(self, path=None):
        self.events: List[Dict[str, Any]] = []
        self.runlog = RunLog(path) if path is not None else None
        self.meta(epoch=time.perf_counter())

    def _emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)
        if self.runlog is not None:
            self.runlog.append(event)

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def metric(self, name: str, values: Optional[Dict[str, Any]] = None,
               **attrs) -> None:
        self._emit({"kind": "metric", "name": name,
                    "values": _jsonable(values or {}),
                    "t0": time.perf_counter(), **attrs})

    def meta(self, **fields) -> None:
        self._emit({"kind": "meta", **_jsonable(fields)})

    def close(self) -> None:
        if self.runlog is not None:
            self.runlog.close()


def _jsonable(values: Dict[str, Any]) -> Dict[str, Any]:
    """Device/numpy values -> JSON-serializable (this is the ONE host
    readback point for device metrics — only reached with tracing on)."""
    out = {}
    for k, v in values.items():
        if isinstance(v, (str, bool, type(None))):
            out[k] = v
        elif np.isscalar(v):
            out[k] = float(v)
        elif isinstance(v, dict):
            out[k] = _jsonable(v)
        else:
            arr = np.asarray(v)
            out[k] = float(arr) if arr.ndim == 0 else arr.tolist()
    return out


# ---------------------------------------------------------------------------
# global active tracer
# ---------------------------------------------------------------------------

_NULL = NullTracer()
_ACTIVE: NullTracer = _NULL


def activate(tracer: Tracer) -> Tracer:
    global _ACTIVE
    _ACTIVE = tracer
    return tracer


def deactivate() -> None:
    global _ACTIVE
    _ACTIVE = _NULL


def get_tracer() -> NullTracer:
    return _ACTIVE


def is_active() -> bool:
    return _ACTIVE.active


@contextlib.contextmanager
def active(tracer: Tracer):
    """Activate ``tracer`` for the duration of the block (restores the
    previous tracer on exit; does NOT close — callers own the sink)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    try:
        yield tracer
    finally:
        _ACTIVE = prev


@contextlib.contextmanager
def suspended():
    """Temporarily disable tracing (the overhead-gate baseline runs under
    this so an outer bench tracer never contaminates the measurement)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = _NULL
    try:
        yield
    finally:
        _ACTIVE = prev


def span(name: str, **attrs):
    return _ACTIVE.span(name, **attrs)


def metric(name: str, values: Optional[Dict[str, Any]] = None, **attrs):
    return _ACTIVE.metric(name, values, **attrs)


# ---------------------------------------------------------------------------
# Chrome-trace / Perfetto export
# ---------------------------------------------------------------------------


def chrome_trace(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Events -> the Chrome-trace ``traceEvents`` JSON (open in
    chrome://tracing or ui.perfetto.dev). Spans become complete ("X")
    events; metrics become instant ("i") events with their values in
    ``args``. Timestamps are rebased to the run's first event."""
    events = list(events)
    t0s = [e.get("t0") for e in events if e.get("t0") is not None]
    epoch = min(t0s) if t0s else 0.0
    trace_events = []
    for e in events:
        if e.get("kind") == "span":
            args = {k: v for k, v in e.items()
                    if k not in ("kind", "name", "t0", "dur")}
            trace_events.append({
                "name": e["name"], "ph": "X", "pid": 0,
                "tid": e.get("cat", "main"),
                "ts": (e["t0"] - epoch) * 1e6, "dur": e["dur"] * 1e6,
                "args": args})
        elif e.get("kind") == "metric":
            trace_events.append({
                "name": e["name"], "ph": "i", "pid": 0, "tid": "metrics",
                "ts": (e.get("t0", epoch) - epoch) * 1e6, "s": "t",
                "args": e.get("values", {})})
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}
