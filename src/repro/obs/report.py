"""Run reporter: summarize a telemetry JSONL into where-the-time-went.

Usage::

    python -m repro.obs.report run.jsonl            # human-readable
    python -m repro.obs.report run.jsonl --json     # machine-readable
    python -m repro.obs.report run.jsonl --chrome trace.json   # Perfetto

The summary has three sections: a per-phase wall-time breakdown (spans
tagged ``cat="phase"`` — gather / local_train / encode / server / apply /
eval — plus the ``cat="stage"`` sub-spans inside the server round), a
per-client table from the LAST round's device metrics (staleness, ring
fill, relevance row mass/density, codec keep-rate and residual-norm),
and the serving snapshot (bucket-exact p50/p99, QPS, queue depth, DRR
deficit spread) if the run served queries.  ``telemetry_block()`` is the
same data shaped for stamping into ``BENCH_*.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Iterable, List, Optional

from repro.obs.trace import RunLog, chrome_trace


def _span_groups(events: List[Dict[str, Any]], cat: str) -> Dict[str, Dict]:
    groups: Dict[str, Dict[str, Any]] = {}
    for e in events:
        if e.get("kind") == "span" and e.get("cat") == cat:
            g = groups.setdefault(e["name"], {"total_s": 0.0, "count": 0,
                                              "max_s": 0.0})
            g["total_s"] += e["dur"]
            g["count"] += 1
            g["max_s"] = max(g["max_s"], e["dur"])
    total = sum(g["total_s"] for g in groups.values())
    for g in groups.values():
        g["mean_s"] = g["total_s"] / g["count"]
        g["share"] = g["total_s"] / total if total > 0 else 0.0
    return groups


def _last_metric(events: List[Dict[str, Any]],
                 name: str) -> Optional[Dict[str, Any]]:
    for e in reversed(events):
        if e.get("kind") == "metric" and e.get("name") == name:
            return e
    return None


def summarize(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Events (from ``Tracer.events`` or ``RunLog.read``) -> summary dict."""
    events = list(events)
    phases = _span_groups(events, "phase")
    stages = _span_groups(events, "stage")

    clients: Dict[str, Any] = {}
    rel = _last_metric(events, "server.relevance")
    if rel:
        clients.update(rel.get("values", {}))
        clients["round"] = rel.get("round")
    enc = _last_metric(events, "comm.encode")
    if enc:
        for k, v in enc.get("values", {}).items():
            clients[k] = v

    serve = _last_metric(events, "serve.stats")
    ivf = _last_metric(events, "serve.ivf")

    n_spans = sum(1 for e in events if e.get("kind") == "span")
    n_metrics = sum(1 for e in events if e.get("kind") == "metric")
    return {
        "events": {"spans": n_spans, "metrics": n_metrics,
                   "total": len(events)},
        "phases": phases,
        "stages": stages,
        "clients": clients,
        "serve": serve.get("values") if serve else None,
        "ivf": ivf.get("values") if ivf else None,
    }


def telemetry_block(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """The ``telemetry`` block ``benchmarks/run.py`` stamps into each
    ``BENCH_*.json``: the span breakdown without the per-client tables
    (those stay in the JSONL — bench files keep fleet-level numbers)."""
    s = summarize(events)
    block: Dict[str, Any] = {"events": s["events"], "phases": s["phases"],
                             "stages": s["stages"]}
    if s["serve"]:
        block["serve"] = s["serve"]
    return block


def _fmt_ms(seconds: float) -> str:
    return f"{seconds * 1e3:9.2f}"


def _print_groups(title: str, groups: Dict[str, Dict]) -> None:
    if not groups:
        return
    print(f"\n{title}")
    print(f"  {'name':<28} {'total ms':>9} {'mean ms':>9} "
          f"{'count':>6} {'share':>6}")
    for name, g in sorted(groups.items(), key=lambda kv: -kv[1]["total_s"]):
        print(f"  {name:<28} {_fmt_ms(g['total_s'])} {_fmt_ms(g['mean_s'])} "
              f"{g['count']:>6} {g['share'] * 100:5.1f}%")


def _print_clients(clients: Dict[str, Any]) -> None:
    cols = [c for c in ("staleness", "hist_fill", "row_mass", "row_density",
                        "self_weight", "keep_rate", "residual_norm")
            if isinstance(clients.get(c), list)]
    if not cols:
        return
    n = len(clients[cols[0]])
    rnd = clients.get("round")
    print(f"\nper-client (last round{'' if rnd is None else f' {rnd}'})")
    print("  " + f"{'client':>6} " + " ".join(f"{c:>13}" for c in cols))
    for i in range(n):
        row = " ".join(f"{clients[c][i]:13.4f}" for c in cols)
        print(f"  {i:>6} {row}")


def _print_serve(serve: Dict[str, Any]) -> None:
    print("\nserving")
    for key in ("latency", "queue", "service"):
        h = serve.get(key)
        if h:
            print(f"  {key:<8} n={h['n']:<7} mean={h['mean_s'] * 1e3:8.3f}ms"
                  f"  p50={h['p50_s'] * 1e3:8.3f}ms"
                  f"  p99={h['p99_s'] * 1e3:8.3f}ms")
    print(f"  completed={serve.get('completed')} "
          f"launches={serve.get('launches')} "
          f"queue_depth(mean/max)={serve.get('queue_depth', {}).get('mean'):.1f}"
          f"/{serve.get('queue_depth', {}).get('max')}")
    if "drr_deficit_spread" in serve:
        print(f"  drr deficit spread={serve['drr_deficit_spread']:.1f}")


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a telemetry JSONL written by repro.obs.")
    p.add_argument("path", help="telemetry JSONL (from --trace / RunLog)")
    p.add_argument("--json", action="store_true",
                   help="print the summary as JSON instead of a table")
    p.add_argument("--chrome", metavar="OUT",
                   help="also write a Chrome-trace/Perfetto JSON to OUT")
    args = p.parse_args(argv)

    events = RunLog.read(args.path)
    s = summarize(events)

    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(chrome_trace(events), f)
        print(f"chrome trace -> {args.chrome}", file=sys.stderr)

    if args.json:
        print(json.dumps(s, indent=2))
        return 0

    print(f"{args.path}: {s['events']['spans']} spans, "
          f"{s['events']['metrics']} metrics")
    _print_groups("phases", s["phases"])
    _print_groups("server stages", s["stages"])
    _print_clients(s["clients"])
    if s["serve"]:
        _print_serve(s["serve"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
