"""Round and serving metric math.

Two halves:

* **Device-side helpers** — pure ``jnp`` functions inlined into EXISTING
  jitted programs (the relevance jit, the codec encode jits, the IVF
  query jit). They compute the round's observables — relevance row
  mass/sparsity, ring-buffer staleness, codec keep-rate/residual-norm,
  IVF probe hit-rates — as extra small outputs of launches that already
  run, so instrumentation adds no host transfers and no extra launches
  (``repro.analysis.lint`` verifies the modified programs).  The host
  only reads these arrays back when a tracer is active.

* **Host-side serving stats** — ``LatencyHistogram`` (fixed log-spaced
  buckets; exact p50/p99 *from the buckets*, i.e. the reported
  percentile is a bucket upper edge — a bounded-relative-error quantile
  that never stores per-sample data), ``RollingMeter`` (windowed QPS),
  and ``ServeStats`` bundling the histograms + queue-depth and DRR
  deficit snapshots the ``ContinuousBatcher`` records into.
"""
from __future__ import annotations

import collections
import math
import time
from typing import Any, Dict, List, Optional

import numpy as np

# ---------------------------------------------------------------------------
# device-side helpers (pure jnp; inlined into existing jitted programs)
# ---------------------------------------------------------------------------


def relevance_metrics(W, valid, stale):
    """Per-client observables of one server relevance step.

    Runs inside the relevance jit: ``W`` is the (C, C) row-normalized
    relevance matrix, ``valid`` the (C, k) ring validity, ``stale`` the
    (C,) rounds-since-last-contribution counter. Returns small (C,)
    arrays only — the host reads them back alongside ``last_W``.
    """
    import jax.numpy as jnp
    row_mass = W.sum(axis=1)                       # ~1.0 unless row was dead
    row_density = (W > 0).mean(axis=1)             # fraction of peers attended
    self_weight = jnp.diagonal(W)                  # Eq.5 self-affinity
    hist_fill = valid.sum(axis=1)                  # ring occupancy per client
    return {"row_mass": row_mass, "row_density": row_density,
            "self_weight": self_weight, "hist_fill": hist_fill,
            "staleness": stale}


def update_staleness(stale, mask):
    """Advance the per-client staleness counter: clients that pushed a
    feature this round (mask > 0) reset to 0, absent clients age by 1.
    This is the signal the FedBuff-style async scheduler (ROADMAP) will
    weight Eq. 6 by."""
    import jax.numpy as jnp
    return jnp.where(mask > 0, jnp.zeros_like(stale), stale + 1.0)


def codec_metrics(residual, kept):
    """Keep-rate + residual-norm of one encode step, per client row.

    ``residual`` is the (C, P) pre-sparsification delta (decoder-reference
    staleness: its norm grows as the reference drifts from the live
    weights); ``kept`` is the (C, P) reconstruction the decoder will see.
    ``kept_energy`` is the fraction of residual energy the wire kept.
    """
    import jax.numpy as jnp
    r2 = jnp.sum(jnp.square(residual), axis=1)
    k2 = jnp.sum(jnp.square(kept), axis=1)
    keep_rate = (kept != 0).mean(axis=1)
    return {"residual_norm": jnp.sqrt(r2),
            "kept_energy": k2 / jnp.maximum(r2, 1e-12),
            "keep_rate": keep_rate}


def ivf_metrics(ids, qmask, idx, bcap, nprobe):
    """IVF shortlist observables, inside the query jit.

    ``ids`` (C, B, nprobe*bcap) are shortlist row ids (-1 = padding);
    ``idx`` (C, B, k) are top-k positions into the shortlist. Returns
    rows-scored per client (how much of the gallery the probes actually
    touched) and the probe-rank histogram of where the final top-k hits
    came from (hit mass at high probe ranks → nprobe too small).
    """
    import jax.numpy as jnp
    m = qmask[:, :, None]
    rows_scored = jnp.sum((ids >= 0) & (m > 0), axis=(1, 2))
    probe_of_hit = idx // bcap                         # (C, B, k)
    onehot = (probe_of_hit[..., None] ==
              jnp.arange(nprobe)[None, None, None, :])
    probe_hits = jnp.sum(onehot * m[..., None], axis=(1, 2))   # (C, nprobe)
    return {"rows_scored": rows_scored, "probe_hits": probe_hits}


# ---------------------------------------------------------------------------
# host-side serving stats
# ---------------------------------------------------------------------------


class LatencyHistogram:
    """Fixed log-spaced latency buckets with exact percentiles *of the
    bucketed distribution*.

    Buckets span [lo, hi) seconds in ``n`` log-uniform steps plus an
    overflow bucket; each recorded sample costs one ``searchsorted``.
    ``percentile(q)`` returns the upper edge of the bucket where the
    cumulative count first reaches ``ceil(q/100 * n)`` — i.e. an upper
    bound on the true sample percentile, tight to one bucket's relative
    width (~15% at the default 64 buckets over 10µs–10s). That is the
    production trade: bounded error, O(1) memory, mergeable.
    """

    def __init__(self, lo: float = 1e-5, hi: float = 10.0, n: int = 64):
        self.edges = np.logspace(math.log10(lo), math.log10(hi), n + 1)
        self.counts = np.zeros(n + 1, dtype=np.int64)   # [+overflow]
        self.n = 0
        self.sum = 0.0

    def record(self, seconds: float) -> None:
        i = int(np.searchsorted(self.edges, seconds, side="right"))
        # i==0 -> below lo: clamp into the first bucket; i>n -> overflow.
        self.counts[min(max(i - 1, 0), len(self.counts) - 1)] += 1
        self.n += 1
        self.sum += seconds

    def record_many(self, seconds) -> None:
        for s in np.asarray(seconds, dtype=np.float64).ravel():
            self.record(float(s))

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket holding the q-th percentile sample.
        Empty histogram -> nan; one sample -> that sample's bucket edge
        for every q."""
        if self.n == 0:
            return float("nan")
        rank = max(1, math.ceil(q / 100.0 * self.n))
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank))
        return float(self.edges[min(i + 1, len(self.edges) - 1)])

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else float("nan")

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        assert self.edges.shape == other.edges.shape
        self.counts += other.counts
        self.n += other.n
        self.sum += other.sum
        return self

    def snapshot(self) -> Dict[str, Any]:
        return {"n": int(self.n), "mean_s": self.mean,
                "p50_s": self.percentile(50), "p99_s": self.percentile(99)}


class RollingMeter:
    """Rolling event rate over a sliding window (default 1 s): ``rate()``
    is events-in-window / window, i.e. instantaneous QPS."""

    def __init__(self, window_s: float = 1.0):
        self.window_s = window_s
        self._stamps: collections.deque = collections.deque()
        self.total = 0

    def tick(self, n: int = 1, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        for _ in range(n):
            self._stamps.append(now)
        self.total += n
        self._evict(now)

    def _evict(self, now: float) -> None:
        cutoff = now - self.window_s
        while self._stamps and self._stamps[0] < cutoff:
            self._stamps.popleft()

    def rate(self, now: Optional[float] = None) -> float:
        now = time.perf_counter() if now is None else now
        self._evict(now)
        return len(self._stamps) / self.window_s


class ServeStats:
    """Everything the serving tier records, in one bag.

    ``ContinuousBatcher.step()`` feeds it per-launch: finished-ticket
    latencies into three histograms (total / queue / service), completions
    into the QPS meter, pre-admission queue depth, and (under DRR) the
    per-client deficit vector. ``snapshot()`` is the JSON-ready summary
    the report CLI and serve bench consume.
    """

    def __init__(self, window_s: float = 1.0):
        self.latency = LatencyHistogram()
        self.queue = LatencyHistogram()
        self.service = LatencyHistogram()
        self.qps = RollingMeter(window_s)
        self.queue_depth: List[int] = []
        self.deficit_snaps: List[List[float]] = []
        self.launches = 0

    def record_ticket(self, ticket) -> None:
        self.latency.record(ticket.latency)
        self.queue.record(ticket.queue_s)
        self.service.record(ticket.service_s)
        self.qps.tick()

    def record_launch(self, depth: int, deficit=None) -> None:
        self.launches += 1
        self.queue_depth.append(int(depth))
        if deficit is not None:
            self.deficit_snaps.append(np.asarray(deficit, np.float64).tolist())

    def snapshot(self) -> Dict[str, Any]:
        depth = np.asarray(self.queue_depth, np.float64)
        out = {
            "latency": self.latency.snapshot(),
            "queue": self.queue.snapshot(),
            "service": self.service.snapshot(),
            "qps_now": self.qps.rate(),
            "completed": int(self.qps.total),
            "launches": int(self.launches),
            "queue_depth": {
                "mean": float(depth.mean()) if depth.size else float("nan"),
                "max": int(depth.max()) if depth.size else 0,
            },
        }
        if self.deficit_snaps:
            last = np.asarray(self.deficit_snaps[-1])
            out["drr_deficit_last"] = last.tolist()
            out["drr_deficit_spread"] = float(last.max() - last.min())
        return out
