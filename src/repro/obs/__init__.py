"""Runtime telemetry: span tracing, device-side round metrics, serving
histograms, and the run reporter (``python -m repro.obs.report``).

Three layers, all off-by-default-cheap:

  * ``obs.trace`` — a span tracer with explicit device-sync boundaries.
    Engines call the module-level ``span()``/``metric()`` helpers, which
    dispatch to the globally active tracer; when none is active they hit
    the null tracer (one attribute load + a no-op context manager, no
    timestamps, no allocation), so instrumented hot loops stay untraced
    for free. ``Tracer`` buffers events in memory and writes JSONL on
    ``close()``; ``chrome_trace`` converts a run to the Chrome-trace /
    Perfetto ``traceEvents`` format.
  * ``obs.metrics`` — device-side metric math that runs INSIDE existing
    jitted programs (relevance row mass/sparsity, ring staleness, codec
    keep-rate/residual-norm, IVF probe hit-rates) plus the host-side
    fixed-bucket ``LatencyHistogram`` / ``RollingMeter`` / ``ServeStats``
    the serving tier records into.
  * ``obs.report`` — ``summarize()`` over a run's events (per-phase time
    breakdown, per-client drift/staleness table, serve percentiles), the
    ``telemetry_block`` the benches stamp into ``BENCH_*.json``, and the
    CLI.
"""
from repro.obs.metrics import (LatencyHistogram, RollingMeter,  # noqa: F401
                               ServeStats)
from repro.obs.trace import (RunLog, Tracer, activate,  # noqa: F401
                             chrome_trace, deactivate, get_tracer,
                             is_active, metric, span, suspended)

__all__ = [
    "Tracer", "RunLog", "chrome_trace", "activate", "deactivate",
    "get_tracer", "is_active", "span", "metric", "suspended",
    "LatencyHistogram", "RollingMeter", "ServeStats",
]
