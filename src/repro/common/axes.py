"""Axis context threading mesh-axis names through model code.

The same forward/backward code runs in two regimes:
  * unsharded (CPU smoke tests, small federated benchmarks): ``AxisCtx()``
    with all axis names None -> every collective helper is a no-op.
  * inside ``shard_map`` over the production mesh: axis names are the mesh
    axis strings and the helpers emit real ``jax.lax`` collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from jax import lax

from repro.common.compat import axis_size, pcast_varying


@dataclasses.dataclass(frozen=True)
class AxisCtx:
    """Names of mesh axes as seen from inside shard_map (None = unsharded)."""

    tp: Optional[str] = None      # tensor/expert parallel axis ("model")
    dp: Optional[str] = None      # data / client parallel axis ("data")
    pod: Optional[str] = None     # cross-pod data axis ("pod")
    fsdp: bool = False            # shard params over dp, all-gather on use
    dp2: Optional[str] = None     # extra batch axis (small-model dp layout:
                                  # the "model" axis carries batch instead)
    decode_ws: bool = False       # weight-stationary decode (no FSDP weight
                                  # gathers; activations move instead)

    @property
    def tp_size(self) -> int:
        return axis_size(self.tp) if self.tp else 1

    @property
    def dp_size(self) -> int:
        return axis_size(self.dp) if self.dp else 1

    def tp_index(self):
        return lax.axis_index(self.tp) if self.tp else 0

    # ---- collective helpers (no-ops when unsharded) ----
    def psum_tp(self, x):
        return lax.psum(x, self.tp) if self.tp else x

    @property
    def dp_axes(self):
        return tuple(a for a in (self.dp, self.pod, self.dp2) if a)

    def psum_dp(self, x):
        axes = self.dp_axes
        return lax.psum(x, axes) if axes else x

    def pmean_dp(self, x):
        axes = self.dp_axes
        return lax.pmean(x, axes) if axes else x

    def all_gather_param(self, w, axis: int):
        """FSDP weight gather: params stored sharded over dp on ``axis``."""
        if self.fsdp and self.dp:
            return lax.all_gather(w, self.dp, axis=axis, tiled=True)
        return w

    def vary(self, x):
        """Mark a literal (scan-carry init etc.) as device-varying over all
        mapped axes — required by shard_map's vma checking, which is what
        makes psum transpose correctly in grad."""
        axes = tuple(a for a in (self.tp, self.dp, self.pod, self.dp2) if a)
        if not axes:
            return x
        return pcast_varying(x, axes)

    def vary_dp(self, x):
        """Vary over the data/pod axes only. Needed for batch-replicated
        decode of FSDP models: gathered weights make layer outputs formally
        data-varying, so the scan carry must start data-varying too."""
        axes = self.dp_axes
        if not axes:
            return x
        return pcast_varying(x, axes)


UNSHARDED = AxisCtx()
