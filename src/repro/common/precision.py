"""Precision policy for the mesh engine: bf16 on the wire, fp32 in state.

The mesh-transformer-jax exemplar (SNIPPETS.md) keeps optimizer state in
fp32 and casts activations/wire traffic to bf16 at shard boundaries. The
sharded federated engine follows the same rule: the flattened ``(C, P)``
upload rows that cross the ``data``/``model`` shard boundary travel as
bf16, and the server upcasts back to fp32 before the relevance-weighted
aggregate (whose normalizer psum must stay fp32 — bf16 accumulation of
10k relevance weights loses the low-order mass).

``to_bf16``/``to_f32`` are pytree-wide casts that only touch float
leaves: int8/int32 wire buffers, bool masks, and index arrays pass
through untouched, so they are safe to apply to mixed codec buffer
dicts. Programs that contain an intentional f32 -> bf16 -> f32
round-trip declare it via ``ProgramSpec.sanctioned_casts`` so the
convert-churn lint knows it is a wire cast, not churn.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# the (src, dst) convert pairs the analysis convert-churn lint accepts in
# programs that declare them: the wire cast down and its matching upcast
WIRE_CASTS = frozenset({("float32", "bfloat16"), ("bfloat16", "float32")})


def _cast_floating(x, dtype):
    if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        return jnp.asarray(x).astype(dtype)
    return x


def to_bf16(tree):
    """Cast every floating leaf to bfloat16 (wire / cross-shard form)."""
    return jax.tree.map(lambda x: _cast_floating(x, jnp.bfloat16), tree)


def to_f32(tree):
    """Cast every floating leaf to float32 (state / accumulate form)."""
    return jax.tree.map(lambda x: _cast_floating(x, jnp.float32), tree)
