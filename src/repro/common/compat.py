"""JAX version-compat shims.

The launch stack targets the modern public API (``jax.shard_map``,
``jax.set_mesh``); on 0.4.x those live under ``jax.experimental`` (with a
``check_rep`` kwarg instead of ``check_vma``) or do not exist at all. Every
call site imports from here so one module owns the version probing.
"""
from __future__ import annotations

import contextlib
import inspect

import jax


def resolve_shard_map(mod=jax):
    """Return the shard_map callable for a given jax module layout.

    New layout: ``mod.shard_map``. Old layout (<= 0.4.x): fall back to
    ``jax.experimental.shard_map.shard_map``.
    """
    fn = getattr(mod, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn
    return fn


def adapt_check_kwarg(param_names, check_vma):
    """Map the modern ``check_vma`` kwarg onto whatever the resolved
    shard_map accepts. None -> library default on the new layout. On 0.4.x
    the replication checker predates the vma type system and rejects valid
    gradient programs (psum-transposed grads of replicated params infer as
    unreplicated), while transposes are correct with or without it — so
    ``check_rep`` is always disabled there."""
    if "check_vma" in param_names:
        return {} if check_vma is None else {"check_vma": check_vma}
    if "check_rep" in param_names:
        return {"check_rep": False}
    return {}


_SHARD_MAP = resolve_shard_map()
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` on any supported JAX version."""
    kwargs.update(adapt_check_kwarg(_SHARD_MAP_PARAMS, check_vma))
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def set_mesh(mesh):
    """Mesh context manager: ``jax.set_mesh`` / ``jax.sharding.use_mesh``
    where available. On 0.4.x shard_map takes the mesh explicitly and jit
    reshards uncommitted inputs itself, so a null context is sufficient."""
    setter = getattr(jax, "set_mesh", None)
    if setter is None:
        setter = getattr(jax.sharding, "use_mesh", None)
    if setter is not None:
        return setter(mesh)
    return contextlib.nullcontext(mesh)


def axis_size(name):
    """``lax.axis_size`` fallback: psum of a unit constant is folded to the
    static axis size on versions that predate the public helper."""
    from jax import lax
    fn = getattr(lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return lax.psum(1, name)


def pcast_varying(x, axes):
    """``lax.pcast(..., to="varying")`` where vma typing exists; identity on
    0.4.x, whose shard_map (check_rep) has no varying-mark requirement."""
    from jax import lax
    fn = getattr(lax, "pcast", None)
    if fn is None:
        return x
    return jax.tree.map(lambda l: fn(l, axes, to="varying"), x)


def default_interpret() -> bool:
    """Pallas kernels only compile for TPU; interpret everywhere else."""
    return jax.default_backend() != "tpu"
