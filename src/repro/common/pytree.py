"""Small pytree helpers used across the framework (optimizers, federated
aggregation, comm accounting)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of elements in a pytree."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(a)))


def tree_bytes(a) -> int:
    """Total number of bytes in a pytree (for communication accounting)."""
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(a)))


def tree_flatten_concat(a):
    """Flatten a pytree of arrays into one 1-D vector + treedef/shapes."""
    leaves, treedef = jax.tree.flatten(a)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l) for l in leaves]) if leaves else jnp.zeros((0,))
    return flat, (treedef, shapes)


def tree_unflatten_concat(flat, meta):
    treedef, shapes = meta
    leaves, off = [], 0
    for s in shapes:
        n = int(np.prod(s)) if len(s) else 1
        leaves.append(jnp.reshape(flat[off:off + n], s))
        off += n
    return jax.tree.unflatten(treedef, leaves)


def tree_stack_flatten(trees):
    """Length-C list of structurally identical pytrees -> ((C, P) fp32
    matrix, meta). The row layout matches ``tree_flatten_concat``; meta
    additionally records per-leaf dtypes so unstacking restores them."""
    leaves0, treedef = jax.tree.flatten(trees[0])
    shapes = [l.shape for l in leaves0]
    dtypes = [l.dtype for l in leaves0]
    rows = [jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                             for l in jax.tree.leaves(t)]) for t in trees]
    return jnp.stack(rows), (treedef, shapes, dtypes)


def tree_unstack_unflatten(mat, meta):
    """(R, P) matrix -> length-R list of pytrees (inverse of
    ``tree_stack_flatten`` up to the fp32 round-trip)."""
    treedef, shapes, dtypes = meta
    sizes = [int(np.prod(s)) if len(s) else 1 for s in shapes]
    offsets = np.cumsum([0] + sizes)
    out = []
    for i in range(mat.shape[0]):
        leaves = [jnp.reshape(mat[i, o:o + n], s).astype(dt)
                  for o, n, s, dt in zip(offsets, sizes, shapes, dtypes)]
        out.append(jax.tree.unflatten(treedef, leaves))
    return out
