"""Small pytree helpers used across the framework (optimizers, federated
aggregation, comm accounting)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of elements in a pytree."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(a)))


def tree_bytes(a) -> int:
    """Total number of bytes in a pytree (for communication accounting)."""
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(a)))


def tree_flatten_concat(a):
    """Flatten a pytree of arrays into one 1-D vector + treedef/shapes."""
    leaves, treedef = jax.tree.flatten(a)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l) for l in leaves]) if leaves else jnp.zeros((0,))
    return flat, (treedef, shapes)


def tree_unflatten_concat(flat, meta):
    treedef, shapes = meta
    leaves, off = [], 0
    for s in shapes:
        n = int(np.prod(s)) if len(s) else 1
        leaves.append(jnp.reshape(flat[off:off + n], s))
        off += n
    return jax.tree.unflatten(treedef, leaves)


def tree_stack(trees):
    """Length-C list of structurally identical pytrees -> one pytree whose
    leaves carry a leading C dim (the stacked-over-clients layout)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree, n: int):
    """Inverse of ``tree_stack``: split the leading dim back into a list."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_slice(tree, i: int):
    """Client ``i``'s slice of a stacked pytree (leaves lose the C dim)."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_stack_flatten(trees):
    """Length-C list of structurally identical pytrees -> ((C, P) fp32
    matrix, meta). The row layout matches ``tree_flatten_concat``; meta
    additionally records per-leaf dtypes so unstacking restores them."""
    leaves0, treedef = jax.tree.flatten(trees[0])
    shapes = [l.shape for l in leaves0]
    dtypes = [l.dtype for l in leaves0]
    rows = [jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                             for l in jax.tree.leaves(t)]) for t in trees]
    return jnp.stack(rows), (treedef, shapes, dtypes)


def tree_flatten_stacked(tree):
    """Stacked pytree (every leaf (C, ...)) -> ((C, P) fp32 matrix, meta).

    Device-resident counterpart of ``tree_stack_flatten``: the input already
    carries the leading client dim, so flattening is a reshape+concat on
    device (no per-client Python loop). Row layout matches
    ``tree_flatten_concat`` / ``tree_stack_flatten``.
    """
    leaves, treedef = jax.tree.flatten(tree)
    C = leaves[0].shape[0]
    shapes = [l.shape[1:] for l in leaves]
    dtypes = [l.dtype for l in leaves]
    mat = jnp.concatenate(
        [jnp.reshape(l, (C, -1)).astype(jnp.float32) for l in leaves], axis=1)
    return mat, (treedef, shapes, dtypes)


def tree_unflatten_stacked(mat, meta):
    """(C, P) matrix -> stacked pytree with leading C dim (inverse of
    ``tree_flatten_stacked`` up to the fp32 round-trip)."""
    treedef, shapes, dtypes = meta
    C = mat.shape[0]
    sizes = [int(np.prod(s)) if len(s) else 1 for s in shapes]
    offsets = np.cumsum([0] + sizes)
    leaves = [jnp.reshape(mat[:, o:o + n], (C,) + tuple(s)).astype(dt)
              for o, n, s, dt in zip(offsets, sizes, shapes, dtypes)]
    return jax.tree.unflatten(treedef, leaves)


def tree_unstack_unflatten(mat, meta):
    """(R, P) matrix -> length-R list of pytrees (inverse of
    ``tree_stack_flatten`` up to the fp32 round-trip)."""
    treedef, shapes, dtypes = meta
    sizes = [int(np.prod(s)) if len(s) else 1 for s in shapes]
    offsets = np.cumsum([0] + sizes)
    out = []
    for i in range(mat.shape[0]):
        leaves = [jnp.reshape(mat[i, o:o + n], s).astype(dt)
                  for o, n, s, dt in zip(offsets, sizes, shapes, dtypes)]
        out.append(jax.tree.unflatten(treedef, leaves))
    return out
