from repro.common.axes import AxisCtx, UNSHARDED
from repro.common.pytree import (
    tree_add,
    tree_scale,
    tree_sub,
    tree_bytes,
    tree_flatten_concat,
    tree_unflatten_concat,
    tree_zeros_like,
    tree_size,
)
