"""Pytree checkpointing (npz-based, no external deps).

Flat key paths ("layers/attn/wq") -> arrays; metadata via a JSON sidecar
entry. Used by the trainer, the federated driver, and the examples.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)

    def fix_lists(node):
        if isinstance(node, dict):
            keys = list(node)
            if keys and all(k.isdigit() for k in keys):
                return [fix_lists(node[str(i)]) for i in range(len(keys))]
            return {k: fix_lists(v) for k, v in node.items()}
        return node
    return fix_lists(root)


def save_checkpoint(path: str, tree, metadata: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    if metadata is not None:
        flat["__meta__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    np.savez(path, **flat)


def load_checkpoint(path: str) -> Tuple[Any, Optional[dict]]:
    """Load a checkpoint written by :func:`save_checkpoint`.

    Raises ``FileNotFoundError`` when the file is missing and
    ``ValueError`` (naming the path) when it exists but is not a
    readable npz archive or its metadata sidecar is not valid JSON —
    a truncated write must fail loudly, not as a deep numpy traceback.
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    try:
        with np.load(path) as npz:
            data = dict(npz)
    except FileNotFoundError:
        raise
    except Exception as e:
        raise ValueError(
            f"corrupt or unreadable checkpoint {path!r}: {e}") from e
    meta = None
    if "__meta__" in data:
        try:
            meta = json.loads(bytes(data.pop("__meta__").tobytes()).decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ValueError(
                f"corrupt checkpoint metadata in {path!r}: {e}") from e
    return _unflatten(data), meta
