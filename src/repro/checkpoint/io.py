"""Pytree checkpointing (npz-based, no external deps).

Flat key paths ("layers/attn/wq") -> arrays; metadata via a JSON sidecar
entry. Used by the trainer, the federated driver, and the examples.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)

    def fix_lists(node):
        if isinstance(node, dict):
            keys = list(node)
            if keys and all(k.isdigit() for k in keys):
                return [fix_lists(node[str(i)]) for i in range(len(keys))]
            return {k: fix_lists(v) for k, v in node.items()}
        return node
    return fix_lists(root)


def save_checkpoint(path: str, tree, metadata: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    if metadata is not None:
        flat["__meta__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    np.savez(path, **flat)


def load_checkpoint(path: str) -> Tuple[Any, Optional[dict]]:
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = dict(np.load(path))
    meta = None
    if "__meta__" in data:
        meta = json.loads(bytes(data.pop("__meta__").tobytes()).decode())
    return _unflatten(data), meta
