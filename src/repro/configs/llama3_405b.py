"""llama3-405b [dense] — GQA, 128k vocab. [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=5e5,
    n_adaptive_layers=1,
    fsdp=True,
    source="arXiv:2407.21783",
)
