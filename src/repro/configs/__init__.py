"""Config registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, LONG_CONTEXT_WINDOW, ModelConfig, ShapeConfig

from repro.configs.qwen1_5_0_5b import CONFIG as _qwen15
from repro.configs.llama3_405b import CONFIG as _llama3
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3moe
from repro.configs.qwen3_1_7b import CONFIG as _qwen3
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6

REGISTRY = {
    c.name: c
    for c in (
        _qwen15, _llama3, _qwen3moe, _qwen3, _zamba2, _arctic, _rwkv6,
    )
}

ARCH_IDS = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


__all__ = [
    "REGISTRY", "ARCH_IDS", "get_config", "get_shape",
    "INPUT_SHAPES", "LONG_CONTEXT_WINDOW", "ModelConfig", "ShapeConfig",
]
