"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B family scaled per assignment]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,                  # per-expert hidden
    vocab_size=151936,
    qk_norm=True,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1e6,
    n_experts=128,
    top_k=8,
    n_adaptive_layers=1,
    fsdp=True,
    source="hf:Qwen/Qwen3-30B-A3B",
)
