"""Architecture + input-shape config system.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` built from :class:`ModelConfig`. ``reduced()`` produces the
CPU-smoke variant (<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    source: str = ""               # citation (paper / model card)

    # attention variants
    qkv_bias: bool = False         # qwen1.5
    qk_norm: bool = False          # qwen3
    rope_theta: float = 10000.0
    causal: bool = True
    sliding_window: int = 0        # 0 = full attention; >0 used for long_500k

    # norm / activation
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "swiglu"            # swiglu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    dense_ff: int = 0              # hidden of the dense residual MLP

    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    attn_every: int = 0            # zamba2: shared attention block period

    # RWKV6
    rwkv_head_size: int = 0

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0               # stub frontend frames (1500 for whisper)

    # VLM
    n_vision_tokens: int = 0       # stub projector output tokens

    # FedSTIL split: how many *last* decoder layers are adaptive (trainable)
    n_adaptive_layers: int = 1

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # distribution
    fsdp: bool = False             # shard params over data axis, gather on use

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def padded_heads(self, tp: int) -> int:
        """Q heads padded so TP divides them (arctic: 56 -> 64 at TP=16)."""
        return _round_up(self.n_heads, tp)

    def padded_vocab(self, tp: int = 256) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size if self.rwkv_head_size else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long_500k decode runs with O(1)/O(W) per-token state."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, hd, V = self.d_model, self.hd, self.padded_vocab()
        emb = V * d * (2 if not self.tied_embeddings else 1)
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.family == "ssm":   # rwkv6
            blk = 6 * d * d + 3 * d * self.d_ff
            return emb + self.n_layers * blk
        if self.act == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.n_experts:
            moe = self.n_experts * (3 * d * self.d_ff)
            if self.dense_residual:
                moe += 3 * d * (self.dense_ff or self.d_ff)
            blk = attn + moe
        elif self.family == "hybrid":
            di = self.d_inner
            mamba = d * (2 * di + di // self.ssm_head_dim * 0) + 2 * d * di + di * d
            blk = mamba + mlp
        else:
            blk = attn + mlp
        n = emb + self.n_layers * blk
        if self.n_enc_layers:
            n += self.n_enc_layers * (attn + mlp) + self.n_layers * (attn)  # cross-attn
        return int(n)

    def active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        dense = self.n_params() - self.n_layers * self.n_experts * 3 * d * self.d_ff
        return int(dense + self.n_layers * self.top_k * 3 * d * self.d_ff)

    def adaptive_active_params(self) -> int:
        """Active params of the trainable (adaptive) slice: last
        n_adaptive_layers + head (FedSTIL split)."""
        per_layer = (self.active_params()
                     - 2 * self.padded_vocab() * self.d_model) / max(self.n_layers, 1)
        head = self.padded_vocab() * self.d_model
        return int(self.n_adaptive_layers * per_layer + head)

    tied_embeddings: bool = False

    def reduced(self) -> "ModelConfig":
        """CPU smoke variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=d,
            n_heads=heads,
            n_kv_heads=max(1, kv if kv <= heads else heads),
            head_dim=64 if self.head_dim else 0,
            d_ff=min(self.d_ff, 512),
            dense_ff=min(self.dense_ff, 512) if self.dense_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            rwkv_head_size=min(self.rwkv_head_size, 32) if self.rwkv_head_size else 0,
            enc_seq=min(self.enc_seq, 16) if self.enc_seq else 0,
            n_vision_tokens=min(self.n_vision_tokens, 8) if self.n_vision_tokens else 0,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            param_dtype="float32",
            compute_dtype="float32",
            fsdp=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Sliding-window size used for long_500k decode on full-attention families.
LONG_CONTEXT_WINDOW = 8192
