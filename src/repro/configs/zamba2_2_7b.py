"""zamba2-2.7b [hybrid] — Mamba2 trunk + shared attention blocks.
[arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,               # mamba2 layers
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    norm="rmsnorm",
    act="gelu",
    rope_theta=10000.0,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,              # shared (weight-tied) attention block period
    n_adaptive_layers=1,
    source="arXiv:2411.15242",
)
