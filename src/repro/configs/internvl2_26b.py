"""internvl2-26b [vlm] — InternViT vision encoder is a stub (precomputed patch
embeddings); this is the InternLM2 language backbone. [arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1e6,
    n_vision_tokens=256,       # projector output tokens (stub frontend)
    n_adaptive_layers=1,
    fsdp=True,
    source="arXiv:2404.16821",
)
