"""whisper-medium [audio] — enc-dec transformer backbone; conv/mel frontend is
a stub per assignment. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,               # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    rope_theta=0.0,            # whisper uses learned/sinusoidal positions
    enc_seq=1500,              # stub frame embeddings (30s audio @ 50Hz)
    n_adaptive_layers=1,
    source="arXiv:2212.04356",
)
