"""rwkv6-1.6b [ssm] — Finch, attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                # d_model / rwkv_head_size
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    norm="layernorm",
    act="gelu",                # rwkv uses squared relu in channel mix (custom)
    rwkv_head_size=64,
    n_adaptive_layers=1,
    source="arXiv:2404.05892",
)
