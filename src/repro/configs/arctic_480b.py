"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,                # padded to 64 under TP=16 (see DESIGN.md)
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,                 # per-expert hidden
    vocab_size=32000,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10000.0,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    dense_ff=4864,
    n_adaptive_layers=1,
    fsdp=True,
    source="hf:Snowflake/snowflake-arctic-base",
)
