"""repro: production-grade JAX reproduction of FedSTIL (spatial-temporal
federated lifelong learning for person ReID) with a multi-architecture
model zoo, multi-pod sharding, and Pallas TPU kernels."""

__version__ = "1.0.0"
