"""Core transformer layers, written to run both unsharded (CPU smoke tests)
and inside ``shard_map`` over the production mesh (Megatron-style TP).

Conventions:
  * params are nested dicts of jnp arrays; *local* shapes inside shard_map
    (head/ff/vocab dims divided by TP), full shapes when unsharded.
  * every function takes an :class:`AxisCtx`; collectives are no-ops when the
    ctx axes are None, so a single code path serves tests and the dry-run.
  * attention is never materialized as a full (S x S) score tensor: prefill /
    train uses chunked online-softmax (Rabe&Staats / flash-style) via
    ``lax.scan`` over KV blocks; decode uses a sequence-sharded KV cache with
    a flash-decoding partial-softmax merge over the ``model`` axis.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.axes import AxisCtx, UNSHARDED
from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_params(cfg: ModelConfig, d: int):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        out = xf * lax.rsqrt(ms + 1e-6) * p["scale"]
    return out.astype(x.dtype)


def rms_head_norm(scale, x):
    """qk-norm: RMS over the head_dim of (B,S,H,hd)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * lax.rsqrt(ms + 1e-6) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd), positions: (..., S) int32."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings (vocab-sharded over TP)
# ---------------------------------------------------------------------------


def embed_params(key, cfg: ModelConfig, vocab_local: int):
    dt = jnp.dtype(cfg.param_dtype)
    return {"table": _dense_init(key, (vocab_local, cfg.d_model), dt, scale=0.02)}


def embed_lookup(cfg: ModelConfig, p, ids, ax: AxisCtx):
    """ids (B,S) int32 with *global* vocab ids; table is vocab-sharded."""
    table = p["table"]
    v_loc = table.shape[0]
    off = ax.tp_index() * v_loc
    local = ids - off
    valid = (local >= 0) & (local < v_loc)
    local = jnp.clip(local, 0, v_loc - 1)
    emb = jnp.take(table, local, axis=0)
    emb = jnp.where(valid[..., None], emb, jnp.zeros_like(emb))
    return ax.psum_tp(emb)


def sinusoidal_positions(seq: int, d: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    ang = pos[:, None] * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


# ---------------------------------------------------------------------------
# LM head: vocab-sharded cross entropy (stable, fp32)
# ---------------------------------------------------------------------------


def head_params(key, cfg: ModelConfig, vocab_local: int):
    dt = jnp.dtype(cfg.param_dtype)
    return {"w": _dense_init(key, (cfg.d_model, vocab_local), dt)}


def _local_vocab_mask(cfg: ModelConfig, v_loc: int, ax: AxisCtx):
    """Mask out vocab-padding columns (global id >= true vocab)."""
    gid = ax.tp_index() * v_loc + jnp.arange(v_loc)
    return gid < cfg.vocab_size


def lm_head_loss(cfg: ModelConfig, p, x, targets, ax: AxisCtx, weights=None):
    """Mean cross-entropy with the vocab dim sharded over TP.

    x: (B,S,d), targets: (B,S) global ids. Returns scalar mean loss.
    """
    logits = jnp.einsum("bsd,dv->bsv", x, p["w"]).astype(jnp.float32)
    v_loc = logits.shape[-1]
    vmask = _local_vocab_mask(cfg, v_loc, ax)
    logits = jnp.where(vmask, logits, -1e30)

    m_loc = jnp.max(logits, -1)
    # the softmax max-shift is gradient-free; pmax has no JVP rule
    m = m_loc if ax.tp is None else lax.stop_gradient(
        lax.pmax(lax.stop_gradient(m_loc), ax.tp))
    se = jnp.sum(jnp.exp(logits - m[..., None]), -1)
    lse = jnp.log(ax.psum_tp(se)) + m

    off = ax.tp_index() * v_loc
    local_t = targets - off
    valid = (local_t >= 0) & (local_t < v_loc)
    local_t = jnp.clip(local_t, 0, v_loc - 1)
    tgt_logit = jnp.take_along_axis(logits, local_t[..., None], -1)[..., 0]
    tgt_logit = jnp.where(valid, tgt_logit, 0.0)
    tgt_logit = ax.psum_tp(tgt_logit)

    nll = lse - tgt_logit
    if weights is None:
        return jnp.mean(nll)
    return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def lm_head_logits(cfg: ModelConfig, p, x, ax: AxisCtx):
    """Full (gathered) logits for decode sampling: (B,S,V_local)->argmax id."""
    logits = jnp.einsum("bsd,dv->bsv", x, p["w"]).astype(jnp.float32)
    v_loc = logits.shape[-1]
    vmask = _local_vocab_mask(cfg, v_loc, ax)
    logits = jnp.where(vmask, logits, -1e30)
    # local argmax + value, then global argmax via pmax trick
    loc_idx = jnp.argmax(logits, -1)
    loc_val = jnp.max(logits, -1)
    gid = loc_idx + ax.tp_index() * v_loc
    if ax.tp is None:
        return gid, loc_val
    best = lax.pmax(loc_val, ax.tp)
    mine = jnp.where(loc_val >= best, gid, -1)
    return lax.pmax(mine, ax.tp), best


# ---------------------------------------------------------------------------
# weight-stationary decode matmuls (§Perf hillclimb: FSDP archs decode)
#
# Baseline FSDP decode all-gathers EVERY weight over the data axis EVERY
# token (llama3-405b: ~3.5 GB/device/token -> 277 ms collective-bound).
# Weight-stationary keeps weights sharded and moves ACTIVATIONS instead
# (a few MB/token): gather x over data, contract the local k-slice, psum.
# ---------------------------------------------------------------------------


def ws_colshard_matmul(x, w, ax: AxisCtx, bias=None):
    """x: (B_loc, 1, d) row-local; w: (d/dp, cols_loc) — contraction dim
    FSDP-sharded over data. Returns (B_loc, 1, cols_loc)."""
    xg = lax.all_gather(x, ax.dp, axis=0, tiled=True)       # (B_tot, 1, d)
    k_loc = w.shape[0]
    idx = lax.axis_index(ax.dp)
    xk = lax.dynamic_slice_in_dim(xg, idx * k_loc, k_loc, axis=2)
    part = jnp.einsum("bsd,dk->bsk", xk, w)
    full = lax.psum(part, ax.dp)                             # (B_tot,1,cols)
    B_loc = x.shape[0]
    out = lax.dynamic_slice_in_dim(full, idx * B_loc, B_loc, axis=0)
    if bias is not None:
        out = out + bias
    return out


def ws_rowshard_matmul(o, w, ax: AxisCtx):
    """o: (B,1,K_loc) with K sharded over model; w: (K_loc, d/dp) — output
    dim FSDP-sharded over data. Returns (B,1,d) full (psum TP + gather dp)."""
    part = jnp.einsum("bsf,fd->bsd", o, w)                   # (B,1,d/dp)
    part = ax.psum_tp(part)
    return lax.all_gather(part, ax.dp, axis=2, tiled=True)   # (B,1,d)


def _use_ws(ax: AxisCtx) -> bool:
    return bool(ax.decode_ws and ax.fsdp and ax.dp)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_params(key, cfg: ModelConfig, ax_tp_size: int, cross: bool = False):
    """Global param shapes; TP-local shapes are produced by the sharder.

    q heads padded to a multiple of TP (arctic 56 -> 64); kv weights are
    replicated when n_kv < TP and each device statically slices its group.
    """
    dt = jnp.dtype(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.hd
    hp = cfg.padded_heads(ax_tp_size)
    keys = jax.random.split(key, 8)
    p = {
        "wq": _dense_init(keys[0], (d, hp * hd), dt),
        "wk": _dense_init(keys[1], (d, cfg.n_kv_heads * hd), dt),
        "wv": _dense_init(keys[2], (d, cfg.n_kv_heads * hd), dt),
        "wo": _dense_init(keys[3], (hp * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hp * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    if cfg.qk_norm:
        p["qnorm"] = jnp.ones((hd,), jnp.float32)
        p["knorm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, p, x, x_kv, ax: AxisCtx, positions, kv_positions):
    """Returns q (B,S,KVg,R,hd), k,v (B,Skv,KVg,hd) with the local GQA layout.

    KVg = local kv heads, R = local q heads per local kv head.
    """
    hd = cfg.hd
    if _use_ws(ax):
        # §Perf iteration 2: ONE x-gather + ONE psum for q,k,v (weights
        # concatenated at trace time) instead of three of each.
        wqkv = jnp.concatenate([p["wq"], p["wk"], p["wv"]], axis=1)
        bias = (jnp.concatenate([p["bq"], p["bk"], p["bv"]])
                if cfg.qkv_bias else None)
        qkv = ws_colshard_matmul(x, wqkv, ax, bias)
        nq = p["wq"].shape[1]
        nk = p["wk"].shape[1]
        q = qkv[..., :nq]
        k = qkv[..., nq:nq + nk]
        v = qkv[..., nq + nk:]
    else:
        wq = ax.all_gather_param(p["wq"], 0)
        wk = ax.all_gather_param(p["wk"], 0)
        wv = ax.all_gather_param(p["wv"], 0)
        q = jnp.einsum("bsd,dh->bsh", x, wq)
        k = jnp.einsum("bsd,dh->bsh", x_kv, wk)
        v = jnp.einsum("bsd,dh->bsh", x_kv, wv)
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]

    B, S = x.shape[0], x.shape[1]
    Skv = x_kv.shape[1]
    h_loc = q.shape[-1] // hd
    kv_cols = k.shape[-1] // hd
    q = q.reshape(B, S, h_loc, hd)
    k = k.reshape(B, Skv, kv_cols, hd)
    v = v.reshape(B, Skv, kv_cols, hd)

    # kv replicated case (n_kv < TP): slice my group's single kv head.
    tp = ax.tp_size
    if ax.tp is not None and cfg.n_kv_heads < tp:
        group = tp // cfg.n_kv_heads          # devices per kv head
        kv_idx = ax.tp_index() // group
        k = lax.dynamic_slice_in_dim(k, kv_idx, 1, axis=2)
        v = lax.dynamic_slice_in_dim(v, kv_idx, 1, axis=2)
        kvg = 1
    else:
        kvg = kv_cols
    r = h_loc // kvg

    if cfg.qk_norm:
        q = rms_head_norm(p["qnorm"], q)
        k = rms_head_norm(p["knorm"], k)
    if cfg.rope_theta > 0 and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)

    q = q.reshape(B, S, kvg, r, hd)
    return q, k, v


def chunked_attention(q, k, v, *, causal, window, q0, k0, chunk=1024,
                      softmax_scale=None, ax: AxisCtx = UNSHARDED):
    """Online-softmax attention, scanning KV in blocks (no SxS in HLO).

    q: (B,Sq,KVg,R,hd); k,v: (B,Sk,KVg,hd). q0/k0: absolute position of the
    first query / key (ints or traced scalars). window>0 = sliding window.
    """
    B, Sq, KVg, R, hd = q.shape
    Sk = k.shape[1]
    scale = softmax_scale or (1.0 / math.sqrt(hd))
    qf = q.astype(jnp.float32) * scale
    chunk = min(chunk, Sk)
    n_chunks = (Sk + chunk - 1) // chunk
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KVg, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KVg, hd).transpose(1, 0, 2, 3, 4)

    qpos = q0 + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        kpos = k0 + ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqgrh,bkgh->bgrqk", qf, kb.astype(jnp.float32))
        mask = jnp.ones((Sq, chunk), bool)
        mask &= (kpos < k0 + Sk)[None, :]                      # kv padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window and window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, -1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgh->bgrqh", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = ax.vary(jnp.full((B, KVg, R, Sq), -1e30, jnp.float32))
    l0 = ax.vary(jnp.zeros((B, KVg, R, Sq), jnp.float32))
    a0 = ax.vary(jnp.zeros((B, KVg, R, Sq, hd), jnp.float32))
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B,KVg,R,Sq,hd) -> (B,Sq,KVg*R,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, KVg * R, hd)
    return out.astype(q.dtype)


def attention_block(cfg: ModelConfig, p, x, ax: AxisCtx, *, positions,
                    x_kv=None, kv_positions=None, causal=None, window=0):
    """Full attention for train/prefill. Returns (B,S,d) after o-proj psum."""
    causal = cfg.causal if causal is None else causal
    x_kv = x if x_kv is None else x_kv
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(cfg, p, x, x_kv, ax, positions, kv_positions)
    out = chunked_attention(q, k, v, causal=causal, window=window, q0=0, k0=0,
                            ax=ax)
    B, S = out.shape[0], out.shape[1]
    wo = ax.all_gather_param(p["wo"], 1)
    y = jnp.einsum("bsf,fd->bsd", out.reshape(B, S, -1), wo)
    return ax.psum_tp(y)


# -- decode: sequence-sharded KV cache + flash-decoding merge ---------------


def init_kv_cache(cfg: ModelConfig, batch_local, seq_local, dtype):
    """dtype=int8 -> quantized cache with per-(token, head) fp scales
    (§Perf decode iteration 3: halves the dominant HBM term)."""
    hd = cfg.hd
    cache = {
        "k": jnp.zeros((batch_local, seq_local, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch_local, seq_local, cfg.n_kv_heads, hd), dtype),
    }
    if dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros((batch_local, seq_local, cfg.n_kv_heads),
                                     jnp.bfloat16)
        cache["v_scale"] = jnp.zeros((batch_local, seq_local, cfg.n_kv_heads),
                                     jnp.bfloat16)
    return cache


def _quantize_kv(x):
    """x: (B,1,KV,hd) -> (int8 values, (B,1,KV) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def decode_attention_block(cfg: ModelConfig, p, x, cache, pos, ax: AxisCtx,
                           *, window=0, inject=True, kv_len=None,
                           ring_window=0):
    """One-token decode against a seq-sharded cache.

    x: (B,1,d); cache k/v: (B, S_loc, KV, hd) — the seq dim is sharded over
    the model axis; every device attends its chunk for ALL heads (q/k/v are
    all-gathered over TP — a few KB), partial softmax stats are psum-merged
    (flash-decoding), and the o-projection returns to the TP layout.
    pos: scalar int32 — current absolute position (cache filled to pos).
    inject=False: cross-attention decode (static cache, e.g. whisper
    encoder outputs); ``kv_len`` then gives the number of valid cache slots.
    Returns (y (B,1,d), new_cache).
    """
    q, k_new, v_new = _project_qkv(
        cfg, p, x, x, ax,
        positions=jnp.full((x.shape[0], 1), pos, jnp.int32),
        kv_positions=jnp.full((x.shape[0], 1), pos, jnp.int32))
    B = x.shape[0]
    hd = cfg.hd
    # gather all heads on every device (tiny tensors: one token)
    if ax.tp is not None:
        q = lax.all_gather(q, ax.tp, axis=2, tiled=True)       # (B,1,KV?,R,hd)
        if inject:
            if cfg.n_kv_heads < ax.tp_size:
                # each group computed the same kv head; take one copy per head
                group = ax.tp_size // cfg.n_kv_heads
                kg = lax.all_gather(k_new, ax.tp, axis=2, tiled=True)
                vg = lax.all_gather(v_new, ax.tp, axis=2, tiled=True)
                k_new = kg[:, :, ::group]
                v_new = vg[:, :, ::group]
            else:
                k_new = lax.all_gather(k_new, ax.tp, axis=2, tiled=True)
                v_new = lax.all_gather(v_new, ax.tp, axis=2, tiled=True)
    KV = cfg.n_kv_heads
    Hp = q.shape[2] * q.shape[3]
    q = q.reshape(B, 1, KV, Hp // KV, hd)

    S_loc = cache["k"].shape[1]
    tp_idx = ax.tp_index()
    quantized = cache["k"].dtype == jnp.int8
    new_scales = {}
    if inject:
        # cache slot owner: device pos // S_loc; masked update everywhere.
        # ring_window>0: the cache is a ring buffer of that many slots
        # (sliding-window long-context decode), slot = pos % window.
        wpos = (pos % ring_window) if ring_window else pos
        slot = wpos - tp_idx * S_loc
        in_range = (slot >= 0) & (slot < S_loc)
        slot_c = jnp.clip(slot, 0, S_loc - 1)

        def upd(c, new):
            newc = lax.dynamic_update_slice_in_dim(
                c, new.astype(c.dtype), slot_c, axis=1)
            return jnp.where(in_range, newc, c)

        if quantized:
            kq, ks = _quantize_kv(k_new)
            vq, vs = _quantize_kv(v_new)
            k_cache = upd(cache["k"], kq)
            v_cache = upd(cache["v"], vq)
            new_scales["k_scale"] = upd(cache["k_scale"], ks)
            new_scales["v_scale"] = upd(cache["v_scale"], vs)
        else:
            k_cache = upd(cache["k"], k_new)
            v_cache = upd(cache["v"], v_new)
    else:
        k_cache, v_cache = cache["k"], cache["v"]
    if quantized:
        k_eff = _dequantize_kv(k_cache, new_scales.get("k_scale",
                                                       cache["k_scale"]))
        v_eff = _dequantize_kv(v_cache, new_scales.get("v_scale",
                                                       cache["v_scale"]))
    else:
        k_eff, v_eff = k_cache, v_cache

    # local partial attention over my seq chunk
    scale = 1.0 / math.sqrt(hd)
    kpos = tp_idx * S_loc + jnp.arange(S_loc)
    if inject:
        if ring_window:
            # ring entries are by construction the last `window` tokens;
            # before the first wrap only slots <= pos are populated
            valid = (kpos <= pos) | (pos >= ring_window)
        else:
            valid = kpos <= pos
            if window and window > 0:
                valid &= kpos > pos - window
    else:
        valid = kpos < (kv_len if kv_len is not None else S_loc * max(ax.tp_size, 1))
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bqgrh,bkgh->bgrqk", qf, k_eff.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    m_loc = jnp.max(s, -1)
    p_ = jnp.exp(s - m_loc[..., None])
    l_loc = jnp.sum(p_, -1)
    o_loc = jnp.einsum("bgrqk,bkgh->bgrqh", p_, v_eff.astype(jnp.float32))
    if ax.tp is not None:
        m = lax.pmax(m_loc, ax.tp)
        corr = jnp.exp(m_loc - m)
        l = lax.psum(l_loc * corr, ax.tp)
        o = lax.psum(o_loc * corr[..., None], ax.tp)
    else:
        l, o = l_loc, o_loc
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, Hp, hd)       # all heads

    # o-proj: keep TP layout — each device uses only its head slice
    if _use_ws(ax):
        h_loc = p["wo"].shape[0] // hd
        o = lax.dynamic_slice_in_dim(o, tp_idx * h_loc, h_loc, axis=2)
        y = ws_rowshard_matmul(o.reshape(B, 1, -1).astype(x.dtype),
                               p["wo"], ax)
    else:
        wo = ax.all_gather_param(p["wo"], 1)
        h_loc = wo.shape[0] // hd
        if ax.tp is not None:
            o = lax.dynamic_slice_in_dim(o, tp_idx * h_loc, h_loc, axis=2)
        y = jnp.einsum("bsf,fd->bsd", o.reshape(B, 1, -1).astype(x.dtype), wo)
        y = ax.psum_tp(y)
    if not inject:
        return y, cache
    new_cache = {"k": k_cache, "v": v_cache, **new_scales}
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------


def mlp_params(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    dt = jnp.dtype(cfg.param_dtype)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "wi": _dense_init(keys[0], (d, f), dt),
            "wg": _dense_init(keys[1], (d, f), dt),
            "wo": _dense_init(keys[2], (f, d), dt),
        }
    return {
        "wi": _dense_init(keys[0], (d, f), dt),
        "wo": _dense_init(keys[2], (f, d), dt),
    }


def mlp_block(cfg: ModelConfig, p, x, ax: AxisCtx):
    if _use_ws(ax):
        # §Perf iteration 2: single gather/psum for wi+wg (concatenated)
        if cfg.act == "swiglu":
            wig = jnp.concatenate([p["wi"], p["wg"]], axis=1)
            hg = ws_colshard_matmul(x, wig, ax)
            f_loc = p["wi"].shape[1]
            h = jax.nn.silu(hg[..., f_loc:]) * hg[..., :f_loc]
        else:
            h = jax.nn.gelu(ws_colshard_matmul(x, p["wi"], ax))
        return ws_rowshard_matmul(h, p["wo"], ax)
    wi = ax.all_gather_param(p["wi"], 0)
    wo = ax.all_gather_param(p["wo"], 1)
    h = jnp.einsum("bsd,df->bsf", x, wi)
    if cfg.act == "swiglu":
        wg = ax.all_gather_param(p["wg"], 0)
        g = jnp.einsum("bsd,df->bsf", x, wg)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("bsf,fd->bsd", h, wo)
    return ax.psum_tp(y)
