"""Mamba2-style selective SSM block (Zamba2 trunk layer).

TPU adaptation: channels (d_inner) and SSM heads are sharded over the
``model`` axis; B/C projections (state dim, ngroups=1) are computed
replicated (they are tiny). Time recurrence is a ``lax.scan`` over chunks —
the state (B, nh, hd, ds) is the decode-time cache. The depthwise causal
conv keeps a (k-1)-step tail as decode state.

Simplifications vs the reference CUDA kernel (recorded in DESIGN.md): the
conv is applied to x only (not B/C), ngroups=1, and the intra-chunk compute
uses the sequential form rather than the block-decomposition of SSD — the
recurrence math (h_t = exp(dt*A) h_{t-1} + dt*B_t x_t, y_t = C_t h_t + D x_t)
is the paper-faithful part.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.axes import AxisCtx
from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init


def mamba_params(key, cfg: ModelConfig, tp: int = 1):
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    di = cfg.d_inner                      # global inner dim
    ds = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    keys = jax.random.split(key, 8)
    return {
        "w_zx": _dense_init(keys[0], (d, 2 * di), dt),     # [z, x] col-shard
        "w_bc": _dense_init(keys[1], (d, 2 * ds), dt),     # replicated
        "w_dt": _dense_init(keys[2], (d, nh), dt),         # col-shard (heads)
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": _dense_init(keys[3], (cfg.ssm_conv, di), dt, scale=0.5),
        "conv_b": jnp.zeros((di,), dt),
        "norm": jnp.ones((di,), jnp.float32),
        "w_out": _dense_init(keys[4], (di, d), dt),        # row-shard -> psum
    }


def _causal_depthwise_conv(x, w, b, tail=None):
    """x: (B,L,ci), w: (k,ci) depthwise. tail: (B,k-1,ci) decode state."""
    k = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_tail = xp[:, -(k - 1):, :] if k > 1 else None
    return out + b, new_tail


def init_ssm_state(cfg: ModelConfig, batch, di_local, dtype=jnp.float32):
    nh = di_local // cfg.ssm_head_dim
    return {
        "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di_local), dtype),
    }


def mamba_block(cfg: ModelConfig, p, x, ax: AxisCtx, state=None):
    """x: (B,L,d). Returns (y (B,L,d), new_state). state!=None => decode.

    Local shapes: w_zx col dim = 2*di_loc; heads nh_loc = di_loc/hd.
    """
    B, L, d = x.shape
    hd = cfg.ssm_head_dim
    ds = cfg.ssm_state

    w_zx = ax.all_gather_param(p["w_zx"], 0)
    w_dt = ax.all_gather_param(p["w_dt"], 0)
    w_out = ax.all_gather_param(p["w_out"], 1)

    zx = jnp.einsum("bld,dk->blk", x, w_zx)
    di_loc = zx.shape[-1] // 2
    z, xs = zx[..., :di_loc], zx[..., di_loc:]
    bc = jnp.einsum("bld,dk->blk", x, p["w_bc"]).astype(jnp.float32)
    Bp, Cp = bc[..., :ds], bc[..., ds:]
    dt_r = jnp.einsum("bld,dh->blh", x, w_dt).astype(jnp.float32)

    conv_tail = state["conv"] if state is not None else None
    xs, new_tail = _causal_depthwise_conv(xs, p["conv_w"], p["conv_b"], conv_tail)
    xs = jax.nn.silu(xs)

    nh = di_loc // hd
    xh = xs.reshape(B, L, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_r + p["dt_bias"])                   # (B,L,nh)
    A = -jnp.exp(p["A_log"])                                    # (nh,)
    decay = jnp.exp(dt * A)                                     # (B,L,nh)

    h0 = (state["h"] if state is not None
          else ax.vary(jnp.zeros((B, nh, hd, ds), jnp.float32)))

    def step(h, inp):
        x_t, b_t, c_t, dec_t, dt_t = inp
        # h: (B,nh,hd,ds)
        upd = (dt_t[..., None, None] * x_t[..., None]) * b_t[:, None, None, :]
        h = dec_t[..., None, None] * h + upd
        y = jnp.einsum("bhps,bs->bhp", h, c_t)
        return h, y

    xs_t = xh.transpose(1, 0, 2, 3)                             # (L,B,nh,hd)
    b_t = Bp.transpose(1, 0, 2)
    c_t = Cp.transpose(1, 0, 2)
    dec_t = decay.transpose(1, 0, 2)
    dt_t = dt.transpose(1, 0, 2)
    hN, ys = lax.scan(step, h0, (xs_t, b_t, c_t, dec_t, dt_t))
    y = ys.transpose(1, 0, 2, 3)                                # (B,L,nh,hd)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B, L, di_loc)

    # gated RMSNorm: di is TP-sharded, so the mean-square needs a psum
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    ss = ax.psum_tp(jnp.sum(jnp.square(yf), -1, keepdims=True))
    ms = ss / (di_loc * ax.tp_size)
    yf = yf * lax.rsqrt(ms + 1e-6) * p["norm"]
    out = jnp.einsum("blk,kd->bld", yf.astype(x.dtype), w_out)
    out = ax.psum_tp(out)

    new_state = None
    if state is not None:
        new_state = {"h": hN, "conv": new_tail}
    return out, new_state
