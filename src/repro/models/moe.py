"""Mixture-of-Experts FFN with expert parallelism over the ``model`` axis.

Design (TPU-native, see DESIGN.md §4): activations entering the FFN are
replicated within a model row (post-attention psum), experts are sharded
E/TP per device. Each device gathers the tokens routed to *its* experts via a
sort-based capacity dispatch (gather indices — never a one-hot dispatch
tensor), computes them, scatter-adds into its partial output, and the usual
MLP ``psum`` over the model axis combines expert contributions. The only MoE
communication is therefore the psum the dense MLP already pays.

Capacity: C = ceil(T * top_k / E * capacity_factor); overflow tokens are
dropped (their combine weight never lands), standard switch-style.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.axes import AxisCtx
from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, mlp_params, mlp_block

CAPACITY_FACTOR = 1.25

# §Perf baseline toggle: REPRO_UNFUSED_DENSE=1 restores the pre-hillclimb
# arctic layout (separate dense-residual all-reduce; 3 ARs/layer).
import os as _os
_UNFUSED_DENSE = bool(int(_os.environ.get("REPRO_UNFUSED_DENSE", "0")))


def moe_params(key, cfg: ModelConfig, experts_local: int):
    dt = jnp.dtype(cfg.param_dtype)
    d, f = cfg.d_model, cfg.d_ff
    keys = jax.random.split(key, 5)
    p = {
        "router": _dense_init(keys[0], (d, cfg.n_experts), jnp.float32, scale=0.02),
        "wi": _dense_init(keys[1], (experts_local, d, f), dt),
        "wg": _dense_init(keys[2], (experts_local, d, f), dt),
        "wo": _dense_init(keys[3], (experts_local, f, d), dt),
    }
    if cfg.dense_residual:
        dcfg = dataclasses.replace(cfg, d_ff=cfg.dense_ff or cfg.d_ff)
        p["dense"] = mlp_params(keys[4], dcfg)
    return p


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * CAPACITY_FACTOR)
    return max(8, ((c + 127) // 128) * 128)


def moe_block(cfg: ModelConfig, p, x, ax: AxisCtx):
    """x: (B,S,d) replicated over TP within a data row. Returns (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E = cfg.n_experts
    k = cfg.top_k
    xf = x.reshape(T, d)

    # ---- routing (fp32, replicated) ----
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = lax.top_k(probs, k)                  # (T,k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (switch-style; counts via scatter-add,
    # never a (T*k, E) one-hot)
    me = jnp.mean(probs, 0)                                    # (E,)
    ce_counts = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0)
    ce = ce_counts / (T * k)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based capacity dispatch ----
    C = expert_capacity(cfg, T)
    ef = gate_idx.reshape(T * k)                               # expert per slot
    wf = gate_vals.reshape(T * k)
    tok = jnp.repeat(jnp.arange(T), k)                         # token per slot

    order = jnp.argsort(ef)                                    # stable
    ef_s, tok_s, wf_s = ef[order], tok[order], wf[order]
    # rank of each slot within its expert segment
    seg_start = jnp.searchsorted(ef_s, jnp.arange(E))          # (E,)
    rank = jnp.arange(T * k) - seg_start[ef_s]

    e_loc = p["wi"].shape[0]
    e_off = ax.tp_index() * e_loc
    local = (ef_s >= e_off) & (ef_s < e_off + e_loc) & (rank < C)
    buf_pos = jnp.where(local, (ef_s - e_off) * C + rank, e_loc * C)

    idx_buf = jnp.full((e_loc * C + 1,), T, jnp.int32).at[buf_pos].set(
        tok_s.astype(jnp.int32), mode="drop")[: e_loc * C]
    w_buf = jnp.zeros((e_loc * C + 1,), jnp.float32).at[buf_pos].set(
        wf_s, mode="drop")[: e_loc * C]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], 0)
    gathered = jnp.take(xpad, idx_buf, axis=0).reshape(e_loc, C, d)

    # ---- expert computation (swiglu) ----
    if ax.decode_ws and ax.fsdp and ax.dp:
        # weight-stationary decode: expert weights stay FSDP-sharded on f;
        # the f-partial contraction is psum'd over data (MBs, not GBs).
        h = jnp.einsum("ecd,edf->ecf", gathered, p["wi"])
        g = jnp.einsum("ecd,edf->ecf", gathered, p["wg"])
        h = jax.nn.silu(g) * h
        y = lax.psum(jnp.einsum("ecf,efd->ecd", h, p["wo"]), ax.dp)
    else:
        wi = ax.all_gather_param(p["wi"], 2)
        wg = ax.all_gather_param(p["wg"], 2)
        wo = ax.all_gather_param(p["wo"], 1)
        h = jnp.einsum("ecd,edf->ecf", gathered, wi)
        g = jnp.einsum("ecd,edf->ecf", gathered, wg)
        h = jax.nn.silu(g) * h
        y = jnp.einsum("ecf,efd->ecd", h, wo)
    y = y * w_buf.reshape(e_loc, C, 1).astype(y.dtype)

    # ---- combine: scatter-add back, single psum over the model axis ----
    out = jnp.zeros((T + 1, d), y.dtype).at[idx_buf.reshape(-1)].add(
        y.reshape(-1, d), mode="drop")[:T]
    out = out.reshape(B, S, d)

    if cfg.dense_residual:
        dcfg = dataclasses.replace(cfg, d_ff=cfg.dense_ff or cfg.d_ff)
        if (ax.decode_ws and ax.fsdp and ax.dp) or _UNFUSED_DENSE:
            # decode ws path / §Perf baseline toggle: separate dense psum
            out = ax.psum_tp(out) + mlp_block(dcfg, p["dense"], x, ax)
        else:
            # §Perf: the dense-residual partial sums ride the SAME psum as
            # the expert combine (one AR per FFN instead of two — arctic
            # was 3 ARs/layer, now 2). EXPERIMENTS.md §Perf iteration 1.
            out = ax.psum_tp(out + _mlp_partial(dcfg, p["dense"], x, ax))
    else:
        out = ax.psum_tp(out)
    return out, aux


def _mlp_partial(cfg: ModelConfig, p, x, ax: AxisCtx):
    """mlp_block WITHOUT the trailing psum (caller fuses it)."""
    wi = ax.all_gather_param(p["wi"], 0)
    wo = ax.all_gather_param(p["wo"], 1)
    h = jnp.einsum("bsd,df->bsf", x, wi)
    if cfg.act == "swiglu":
        wg = ax.all_gather_param(p["wg"], 0)
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, wg)) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, wo)
