"""Model assembly for all assigned architecture families.

Parameters are nested dicts with repeated layers *stacked* on a leading L dim
(one ``lax.scan`` per trunk — crucial for compile time at 126 layers).
The same forward code runs unsharded (CPU) and inside shard_map (TP).

Families:
  dense / moe / vlm : decoder-only LM (vlm = stub vision tokens prepended)
  ssm (rwkv6)       : attention-free time-mix/channel-mix stack
  hybrid (zamba2)   : groups of mamba2 layers + one weight-shared attn block
  encdec (whisper)  : stub-frame encoder + causal decoder w/ cross-attention
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.axes import AxisCtx, UNSHARDED
from repro.configs.base import ModelConfig

# REPRO_SCAN_UNROLL=N unrolls the layer scans (validation of the analytic
# roofline vs trip-count-erased while-loops in HLO; see EXPERIMENTS.md).
import os as _os
_SCAN_UNROLL = int(_os.environ.get("REPRO_SCAN_UNROLL", "1"))
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rwkv as RWKV
from repro.models import ssm as SSM

# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------


def _stack_init(init_fn, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _dense_layer_init(cfg: ModelConfig, tp: int):
    def init(key):
        k1, k2 = jax.random.split(key)
        block = {
            "ln1": L.norm_params(cfg, cfg.d_model),
            "attn": L.attention_params(k1, cfg, tp),
            "ln2": L.norm_params(cfg, cfg.d_model),
        }
        if cfg.family == "moe" or (cfg.n_experts and cfg.family != "hybrid"):
            block["moe"] = MOE.moe_params(k2, cfg, cfg.n_experts)
        else:
            block["mlp"] = L.mlp_params(k2, cfg)
        return block
    return init


def _rwkv_layer_init(cfg: ModelConfig):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_params(cfg, cfg.d_model),
            "time": RWKV.rwkv_time_params(k1, cfg),
            "ln2": L.norm_params(cfg, cfg.d_model),
            "chan": RWKV.rwkv_channel_params(k2, cfg),
        }
    return init


def _mamba_layer_init(cfg: ModelConfig):
    def init(key):
        return {"ln": L.norm_params(cfg, cfg.d_model),
                "mamba": SSM.mamba_params(key, cfg)}
    return init


def init_params(cfg: ModelConfig, key, tp: int = 1):
    """Global (unsharded) parameter pytree. The dry-run never calls this with
    real memory — it uses ``jax.eval_shape`` around it."""
    keys = jax.random.split(key, 12)
    vp = cfg.padded_vocab()
    params = {
        "embed": L.embed_params(keys[0], cfg, vp),
        "final_norm": L.norm_params(cfg, cfg.d_model),
        "head": L.head_params(keys[1], cfg, vp),
    }
    n_ad = cfg.n_adaptive_layers

    if cfg.family in ("dense", "moe", "vlm"):
        n_trunk = cfg.n_layers - n_ad
        params["layers"] = _stack_init(_dense_layer_init(cfg, tp), keys[2], n_trunk)
        params["adaptive_layers"] = _stack_init(_dense_layer_init(cfg, tp), keys[3], n_ad)
    elif cfg.family == "ssm":
        n_trunk = cfg.n_layers - n_ad
        params["layers"] = _stack_init(_rwkv_layer_init(cfg), keys[2], n_trunk)
        params["adaptive_layers"] = _stack_init(_rwkv_layer_init(cfg), keys[3], n_ad)
    elif cfg.family == "hybrid":
        params["layers"] = _stack_init(_mamba_layer_init(cfg), keys[2], cfg.n_layers)
        # the weight-shared attention block is the adaptive part
        params["shared_attn"] = _dense_layer_init(cfg, tp)(keys[3])
    elif cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, causal=False)
        def enc_init(key):
            k1, k2 = jax.random.split(key)
            return {
                "ln1": L.norm_params(cfg, cfg.d_model),
                "attn": L.attention_params(k1, enc_cfg, tp),
                "ln2": L.norm_params(cfg, cfg.d_model),
                "mlp": L.mlp_params(k2, cfg),
            }
        def dec_init(key):
            k1, k2, k3 = jax.random.split(key, 3)
            return {
                "ln1": L.norm_params(cfg, cfg.d_model),
                "attn": L.attention_params(k1, cfg, tp),
                "lnx": L.norm_params(cfg, cfg.d_model),
                "cross": L.attention_params(k2, cfg, tp),
                "ln2": L.norm_params(cfg, cfg.d_model),
                "mlp": L.mlp_params(k3, cfg),
            }
        params["enc_layers"] = _stack_init(enc_init, keys[2], cfg.n_enc_layers)
        params["enc_norm"] = L.norm_params(cfg, cfg.d_model)
        n_trunk = cfg.n_layers - n_ad
        params["layers"] = _stack_init(dec_init, keys[4], n_trunk)
        params["adaptive_layers"] = _stack_init(dec_init, keys[5], n_ad)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# layer application (train / prefill)
# ---------------------------------------------------------------------------


def _dense_layer_apply(cfg: ModelConfig, lp, x, ax: AxisCtx, positions, window=0):
    h = L.apply_norm(cfg, lp["ln1"], x)
    x = x + L.attention_block(cfg, lp["attn"], h, ax, positions=positions,
                              window=window)
    h = L.apply_norm(cfg, lp["ln2"], x)
    if "moe" in lp:
        y, aux = MOE.moe_block(cfg, lp["moe"], h, ax)
        return x + y, aux
    return x + L.mlp_block(cfg, lp["mlp"], h, ax), jnp.zeros((), jnp.float32)


def _rwkv_layer_apply(cfg: ModelConfig, lp, x, ax: AxisCtx, state=None):
    h = L.apply_norm(cfg, lp["ln1"], x)
    y, S_new, last_att = RWKV.rwkv_time_mix(cfg, lp["time"], h, ax, state)
    x = x + y
    h = L.apply_norm(cfg, lp["ln2"], x)
    y, last_ffn = RWKV.rwkv_channel_mix(cfg, lp["chan"], h, ax, state)
    x = x + y
    new_state = None
    if state is not None:
        new_state = {"S": S_new, "x_att": last_att, "x_ffn": last_ffn}
    return x, new_state


def _mamba_layer_apply(cfg: ModelConfig, lp, x, ax: AxisCtx, state=None):
    h = L.apply_norm(cfg, lp["ln"], x)
    y, new_state = SSM.mamba_block(cfg, lp["mamba"], h, ax, state)
    return x + y, new_state


def _scan_layers(apply_fn, x, stacked, *extra):
    def body(carry, lp):
        y, aux = apply_fn(carry, lp)
        return y, aux
    x, auxs = lax.scan(lambda c, lp: apply_fn(c, lp), x, stacked)
    return x, auxs


# ---------------------------------------------------------------------------
# forward (train / prefill): returns final hidden states + moe aux
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, params, batch, ax: AxisCtx):
    tokens = batch["tokens"]
    x = L.embed_lookup(cfg, params["embed"], tokens, ax)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x], axis=1)
    if cfg.rope_theta <= 0:  # learned/sinusoidal positions (whisper decoder)
        S = x.shape[1]
        x = x + L.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    return x


def forward(cfg: ModelConfig, params, batch, ax: AxisCtx = UNSHARDED, *, window=0):
    """Trunk + adaptive layers; returns (hidden (B,S,d), moe_aux)."""
    x = _embed_inputs(cfg, params, batch, ax)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm"):
        fn = lambda c, lp: _dense_layer_apply(cfg, lp, c, ax, positions, window)
        x, auxs = lax.scan(fn, x, params["layers"], unroll=_SCAN_UNROLL)
        aux_total += jnp.sum(auxs)
        x, auxs = lax.scan(fn, x, params["adaptive_layers"])
        aux_total += jnp.sum(auxs)

    elif cfg.family == "ssm":
        fn = lambda c, lp: _rwkv_layer_apply(cfg, lp, c, ax)
        x, _ = lax.scan(lambda c, lp: (fn(c, lp)[0], 0.0), x, params["layers"])
        x, _ = lax.scan(lambda c, lp: (fn(c, lp)[0], 0.0), x,
                        params["adaptive_layers"])

    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_groups, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        shared = params["shared_attn"]

        def group_fn(c, glp):
            c, _ = lax.scan(
                lambda cc, lp: (_mamba_layer_apply(cfg, lp, cc, ax)[0], 0.0),
                c, glp)
            c, aux = _dense_layer_apply(cfg, shared, c, ax, positions)
            return c, aux
        x, auxs = lax.scan(group_fn, x, stacked)
        aux_total += jnp.sum(auxs)

    elif cfg.family == "encdec":
        frames = batch["frames"]
        enc = frames.astype(x.dtype) + L.sinusoidal_positions(
            frames.shape[1], cfg.d_model).astype(x.dtype)
        enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1]), enc.shape[:2])

        def enc_fn(c, lp):
            h = L.apply_norm(cfg, lp["ln1"], c)
            c = c + L.attention_block(cfg, lp["attn"], h, ax,
                                      positions=enc_pos, causal=False)
            h = L.apply_norm(cfg, lp["ln2"], c)
            return c + L.mlp_block(cfg, lp["mlp"], h, ax), 0.0
        enc, _ = lax.scan(enc_fn, enc, params["enc_layers"])
        enc = L.apply_norm(cfg, params["enc_norm"], enc)

        def dec_fn(c, lp):
            h = L.apply_norm(cfg, lp["ln1"], c)
            c = c + L.attention_block(cfg, lp["attn"], h, ax,
                                      positions=positions, window=window)
            h = L.apply_norm(cfg, lp["lnx"], c)
            c = c + L.attention_block(cfg, lp["cross"], h, ax,
                                      positions=positions, x_kv=enc,
                                      kv_positions=enc_pos, causal=False)
            h = L.apply_norm(cfg, lp["ln2"], c)
            return c + L.mlp_block(cfg, lp["mlp"], h, ax), 0.0
        x, _ = lax.scan(dec_fn, x, params["layers"])
        x, _ = lax.scan(dec_fn, x, params["adaptive_layers"])
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, aux_total


def loss_fn(cfg: ModelConfig, params, batch, ax: AxisCtx = UNSHARDED, *,
            window=0, aux_weight=0.01):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    x, aux = forward(cfg, params, batch, ax, window=window)
    if cfg.family == "vlm":
        x = x[:, cfg.n_vision_tokens:]
    loss = L.lm_head_loss(cfg, params["head"], x, batch["labels"], ax)
    return loss + aux_weight * aux, (loss, aux)


# ---------------------------------------------------------------------------
# decode (one token against cache/state)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_local: int, seq_local: int, *,
               enc_seq_local: int = 0, dtype=jnp.bfloat16, tp: int = 1):
    """Decode cache pytree (local shapes; seq dim sharded over TP)."""
    n_ad = cfg.n_adaptive_layers
    if cfg.family in ("dense", "moe", "vlm"):
        n_trunk = cfg.n_layers - n_ad
        def mk(n):
            c = {"k": jnp.zeros((n, batch_local, seq_local, cfg.n_kv_heads,
                                 cfg.hd), dtype),
                 "v": jnp.zeros((n, batch_local, seq_local, cfg.n_kv_heads,
                                 cfg.hd), dtype)}
            if dtype == jnp.int8:   # §Perf decode iteration 3
                c["k_scale"] = jnp.zeros(
                    (n, batch_local, seq_local, cfg.n_kv_heads), jnp.bfloat16)
                c["v_scale"] = jnp.zeros(
                    (n, batch_local, seq_local, cfg.n_kv_heads), jnp.bfloat16)
            return c
        return {"trunk": mk(n_trunk), "adaptive": mk(n_ad)}
    if cfg.family == "ssm":
        nh_loc = (cfg.d_model // cfg.rwkv_head_size) // tp
        mk = lambda n: {
            "S": jnp.zeros((n, batch_local, nh_loc, cfg.rwkv_head_size,
                            cfg.rwkv_head_size), jnp.float32),
            "x_att": jnp.zeros((n, batch_local, cfg.d_model), dtype),
            "x_ffn": jnp.zeros((n, batch_local, cfg.d_model), dtype)}
        return {"trunk": mk(cfg.n_layers - n_ad), "adaptive": mk(n_ad)}
    if cfg.family == "hybrid":
        di_loc = cfg.d_inner // tp
        nh_loc = di_loc // cfg.ssm_head_dim
        n_groups = cfg.n_layers // cfg.attn_every
        return {
            "mamba": {
                "h": jnp.zeros((cfg.n_layers, batch_local, nh_loc,
                                cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch_local, cfg.ssm_conv - 1,
                                   di_loc), dtype)},
            "attn": {
                "k": jnp.zeros((n_groups, batch_local, seq_local,
                                cfg.n_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros((n_groups, batch_local, seq_local,
                                cfg.n_kv_heads, cfg.hd), dtype)},
        }
    if cfg.family == "encdec":
        n_trunk = cfg.n_layers - n_ad
        mk_self = lambda n: {
            "k": jnp.zeros((n, batch_local, seq_local, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((n, batch_local, seq_local, cfg.n_kv_heads, cfg.hd), dtype)}
        mk_cross = lambda n: {
            "k": jnp.zeros((n, batch_local, enc_seq_local, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((n, batch_local, enc_seq_local, cfg.n_kv_heads, cfg.hd), dtype)}
        return {"trunk": mk_self(n_trunk), "adaptive": mk_self(n_ad),
                "cross_trunk": mk_cross(n_trunk), "cross_adaptive": mk_cross(n_ad)}
    raise ValueError(cfg.family)


def prefill_cross_cache(cfg: ModelConfig, params, frames, cache,
                        ax: AxisCtx = UNSHARDED):
    """Whisper serving: run the encoder once and fill the cross-attention
    k/v caches of every decoder layer. frames: (B, enc_seq, d_model)."""
    enc = frames + L.sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(frames.dtype)
    enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1]), enc.shape[:2])

    def enc_fn(c, lp):
        h = L.apply_norm(cfg, lp["ln1"], c)
        c = c + L.attention_block(cfg, lp["attn"], h, ax,
                                  positions=enc_pos, causal=False)
        h = L.apply_norm(cfg, lp["ln2"], c)
        return c + L.mlp_block(cfg, lp["mlp"], h, ax), 0.0

    enc, _ = lax.scan(enc_fn, enc, params["enc_layers"])
    enc = L.apply_norm(cfg, params["enc_norm"], enc)

    def fill(lp_stack, cross):
        def one(_, inp):
            lp, cc = inp
            _, k, v = L._project_qkv(cfg, lp["cross"], enc, enc, ax,
                                     positions=None, kv_positions=None)
            pad = cc["k"].shape[1] - k.shape[1]
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(
                cc["k"].dtype)
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(
                cc["v"].dtype)
            return 0.0, {"k": kp, "v": vp}
        _, filled = lax.scan(one, 0.0, (lp_stack, cross))
        return filled

    new_cache = dict(cache)
    new_cache["cross_trunk"] = fill(params["layers"], cache["cross_trunk"])
    new_cache["cross_adaptive"] = fill(params["adaptive_layers"],
                                       cache["cross_adaptive"])
    return new_cache, enc


def decode_step(cfg: ModelConfig, params, cache, token, pos,
                ax: AxisCtx = UNSHARDED, *, window=0, ring=False,
                enc_len=None):
    """One greedy decode step. token: (B,1) int32, pos: scalar int32.

    Returns (next_token (B,1) int32, new_cache). ``ring=True`` treats the
    attention caches as ring buffers of size ``window`` (long_500k).
    """
    x = L.embed_lookup(cfg, params["embed"], token, ax)
    if cfg.rope_theta <= 0:
        B = x.shape[0]
        # position encoding for a single absolute position
        d = cfg.d_model
        posenc = L.sinusoidal_positions(1, d, offset=pos).astype(x.dtype)
        x = x + posenc
    if ax.fsdp:
        # FSDP weight gathers make every layer output formally data-varying;
        # the layer-scan carry must enter with matching vma type.
        x = ax.vary_dp(x)

    ring_w = window if ring else 0

    def attn_dec(lp, c, xx, cache_kv, extra_window=0):
        h = L.apply_norm(cfg, lp["ln1"], xx)
        y, new_kv = L.decode_attention_block(
            cfg, lp["attn"], h, cache_kv, pos, ax,
            window=(0 if ring else window), ring_window=ring_w, inject=True)
        return xx + y, new_kv

    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm"):
        def layer_dec(c, inp):
            lp, kv = inp
            xx, new_kv = attn_dec(lp, None, c, kv)
            h = L.apply_norm(cfg, lp["ln2"], xx)
            if "moe" in lp:
                y, _ = MOE.moe_block(cfg, lp["moe"], h, ax)
            else:
                y = L.mlp_block(cfg, lp["mlp"], h, ax)
            return xx + y, new_kv
        x, new_trunk = lax.scan(layer_dec, x, (params["layers"], cache["trunk"]))
        x, new_ad = lax.scan(layer_dec, x, (params["adaptive_layers"], cache["adaptive"]))
        new_cache = {"trunk": new_trunk, "adaptive": new_ad}

    elif cfg.family == "ssm":
        def layer_dec(c, inp):
            lp, st = inp
            y, new_st = _rwkv_layer_apply(cfg, lp, c, ax, st)
            return y, new_st
        x, new_trunk = lax.scan(layer_dec, x, (params["layers"], cache["trunk"]))
        x, new_ad = lax.scan(layer_dec, x, (params["adaptive_layers"], cache["adaptive"]))
        new_cache = {"trunk": new_trunk, "adaptive": new_ad}

    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        mcache = cache["mamba"]
        g_params = jax.tree.map(
            lambda a: a.reshape((n_groups, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        g_mcache = jax.tree.map(
            lambda a: a.reshape((n_groups, cfg.attn_every) + a.shape[1:]), mcache)
        shared = params["shared_attn"]

        def group_dec(c, inp):
            glp, gmc, kv = inp
            def m_dec(cc, minp):
                lp, st = minp
                return _mamba_layer_apply(cfg, lp, cc, ax, st)
            c, new_mc = lax.scan(m_dec, c, (glp, gmc))
            h = L.apply_norm(cfg, shared["ln1"], c)
            y, new_kv = L.decode_attention_block(
                cfg, shared["attn"], h, kv, pos, ax,
                window=(0 if ring else window), ring_window=ring_w)
            c = c + y
            h = L.apply_norm(cfg, shared["ln2"], c)
            c = c + L.mlp_block(cfg, shared["mlp"], h, ax)
            return c, (new_mc, new_kv)
        x, (new_gmc, new_kv) = lax.scan(
            group_dec, x, (g_params, g_mcache, cache["attn"]))
        new_mcache = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_gmc)
        new_cache = {"mamba": new_mcache, "attn": new_kv}

    elif cfg.family == "encdec":
        def layer_dec(c, inp):
            lp, kv, xkv = inp
            xx, new_kv = attn_dec(lp, None, c, kv)
            h = L.apply_norm(cfg, lp["lnx"], xx)
            y, _ = L.decode_attention_block(
                cfg, lp["cross"], h, xkv, pos, ax, inject=False,
                kv_len=enc_len)
            xx = xx + y
            h = L.apply_norm(cfg, lp["ln2"], xx)
            return xx + L.mlp_block(cfg, lp["mlp"], h, ax), new_kv
        x, new_trunk = lax.scan(
            layer_dec, x, (params["layers"], cache["trunk"], cache["cross_trunk"]))
        x, new_ad = lax.scan(
            layer_dec, x,
            (params["adaptive_layers"], cache["adaptive"], cache["cross_adaptive"]))
        new_cache = {"trunk": new_trunk, "adaptive": new_ad,
                     "cross_trunk": cache["cross_trunk"],
                     "cross_adaptive": cache["cross_adaptive"]}
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)
    next_tok, _ = L.lm_head_logits(cfg, params["head"], x, ax)
    return next_tok.astype(jnp.int32), new_cache
