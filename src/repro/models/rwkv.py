"""RWKV6 ("Finch") block: attention-free time-mix with data-dependent decay
+ channel-mix. [arXiv:2404.05892]

TPU adaptation: heads sharded over the ``model`` axis (r/k/v/g projections
column-sharded by head, output projection row-sharded + psum). The WKV
recurrence is a ``lax.scan`` with per-head matrix state (hd x hd) — this
state is the decode cache (O(1) in sequence length, so long_500k decode is
natively sub-quadratic).

Time-mix (faithful to Finch):
  w_t = exp(-exp(w0 + tanh(x_w @ A_w) @ B_w))          (data-dependent decay)
  S_t = diag-ish decay on k-dim: S_t = w_t ⊙ S_{t-1} + k_t ⊗ v_t
  y_t = r_t · (S_{t-1} + u ⊙ (k_t ⊗ v_t))              (u = "bonus" first hit)

Simplification vs reference (DESIGN.md): single token-shift mix per stream
(r/k/v/w/g share the 5 mu vectors but not the Finch dynamic-mix LoRA on the
shift itself).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.common.axes import AxisCtx
from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init

_DECAY_LORA = 64


def rwkv_time_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    nh = d // hd
    keys = jax.random.split(key, 10)
    return {
        "mu": _dense_init(keys[0], (5, d), jnp.float32, scale=0.2),  # r,k,v,w,g
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "Aw": _dense_init(keys[1], (d, _DECAY_LORA), jnp.float32, scale=0.02),
        "Bw": _dense_init(keys[2], (_DECAY_LORA, d), jnp.float32, scale=0.02),
        "wr": _dense_init(keys[3], (d, d), dt),       # col-shard (heads)
        "wk": _dense_init(keys[4], (d, d), dt),
        "wv": _dense_init(keys[5], (d, d), dt),
        "wg": _dense_init(keys[6], (d, d), dt),
        "u": _dense_init(keys[7], (d,), jnp.float32, scale=0.5),  # head-sharded
        "ln_scale": jnp.ones((d,), jnp.float32),      # per-head groupnorm
        "ln_bias": jnp.zeros((d,), jnp.float32),
        "wo": _dense_init(keys[8], (d, d), dt),       # row-shard -> psum
    }


def rwkv_channel_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    d, f = cfg.d_model, cfg.d_ff
    keys = jax.random.split(key, 3)
    return {
        "mu": _dense_init(keys[0], (2, d), jnp.float32, scale=0.2),  # k, r
        "wk": _dense_init(keys[1], (d, f), dt),       # col-shard
        "wv": _dense_init(keys[2], (f, d), dt),       # row-shard -> psum
        "wr": _dense_init(keys[2], (d, d), dt),       # replicated (gate)
    }


def _token_shift(x, prev):
    """prev: (B,d) last token of previous step (decode) or None (train)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def init_rwkv_state(cfg: ModelConfig, batch, nh_local, dtype=jnp.float32):
    hd = cfg.rwkv_head_size
    return {
        "S": jnp.zeros((batch, nh_local, hd, hd), jnp.float32),
        "x_att": jnp.zeros((batch, cfg.d_model), dtype),
        "x_ffn": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv_time_mix(cfg: ModelConfig, p, x, ax: AxisCtx, state=None):
    """x: (B,L,d) replicated over TP. Returns (y (B,L,d), new_S, last_x)."""
    B, L, d = x.shape
    hd = cfg.rwkv_head_size
    prev = state["x_att"] if state is not None else None
    xx = _token_shift(x, prev)
    xf, xxf = x.astype(jnp.float32), xx.astype(jnp.float32)
    mix = [xf + (xxf - xf) * p["mu"][i] for i in range(5)]
    xr, xk, xv, xw, xg = mix

    wr = ax.all_gather_param(p["wr"], 0)
    wk = ax.all_gather_param(p["wk"], 0)
    wv = ax.all_gather_param(p["wv"], 0)
    wg = ax.all_gather_param(p["wg"], 0)
    wo = ax.all_gather_param(p["wo"], 1)

    r = jnp.einsum("bld,dk->blk", xr.astype(x.dtype), wr)
    k = jnp.einsum("bld,dk->blk", xk.astype(x.dtype), wk)
    v = jnp.einsum("bld,dk->blk", xv.astype(x.dtype), wv)
    g = jnp.einsum("bld,dk->blk", xg.astype(x.dtype), wg)
    d_loc = r.shape[-1]
    nh = d_loc // hd

    # data-dependent decay (fp32), then slice my head block
    w_full = p["w0"] + jnp.einsum("blr,rd->bld", jnp.tanh(
        jnp.einsum("bld,dr->blr", xw, p["Aw"])), p["Bw"])
    w_full = jnp.exp(-jnp.exp(w_full))                          # (B,L,d) global
    if ax.tp is not None:
        off = ax.tp_index() * d_loc
        w_loc = lax.dynamic_slice_in_dim(w_full, off, d_loc, axis=2)
    else:
        w_loc = w_full

    rh = r.reshape(B, L, nh, hd).astype(jnp.float32)
    kh = k.reshape(B, L, nh, hd).astype(jnp.float32)
    vh = v.reshape(B, L, nh, hd).astype(jnp.float32)
    wh = w_loc.reshape(B, L, nh, hd)
    u = p["u"].reshape(nh, hd)

    S0 = (state["S"] if state is not None
          else ax.vary(jnp.zeros((B, nh, hd, hd), jnp.float32)))

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                              # (B,nh,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,nh,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    SN, ys = lax.scan(
        step, S0,
        (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
         vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3)                               # (B,L,nh,hd)

    # per-head groupnorm (head dims are local: no cross-device stats needed)
    mu_ = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    yn = (y - mu_) * lax.rsqrt(var + 1e-5)
    ln_s = p["ln_scale"].reshape(nh, hd)
    ln_b = p["ln_bias"].reshape(nh, hd)
    yn = yn * ln_s + ln_b
    yn = yn.reshape(B, L, d_loc) * jax.nn.silu(g.astype(jnp.float32))

    out = jnp.einsum("blk,kd->bld", yn.astype(x.dtype), wo)
    out = ax.psum_tp(out)
    return out, SN, x[:, -1]


def rwkv_channel_mix(cfg: ModelConfig, p, x, ax: AxisCtx, state=None):
    B, L, d = x.shape
    prev = state["x_ffn"] if state is not None else None
    xx = _token_shift(x, prev)
    xf, xxf = x.astype(jnp.float32), xx.astype(jnp.float32)
    xk = (xf + (xxf - xf) * p["mu"][0]).astype(x.dtype)
    xr = (xf + (xxf - xf) * p["mu"][1]).astype(x.dtype)

    wk = ax.all_gather_param(p["wk"], 0)
    wv = ax.all_gather_param(p["wv"], 1)
    k = jnp.einsum("bld,df->blf", xk, wk)
    k = jnp.square(jax.nn.relu(k))
    kv = ax.psum_tp(jnp.einsum("blf,fd->bld", k, wv))
    r = jax.nn.sigmoid(jnp.einsum("bld,dk->blk", xr, p["wr"]).astype(jnp.float32))
    return (r * kv.astype(jnp.float32)).astype(x.dtype), x[:, -1]
