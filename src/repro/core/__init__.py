"""The paper's primary contribution: FedSTIL — spatial-temporal federated
lifelong learning. Adaptive parameterization (Eq. 2), prototype pipeline
(Eq. 1/3), KL task similarity (Eq. 4), knowledge relevance (Eq. 5),
personalized aggregation (Eq. 6), prototype rehearsal, parameter tying."""

from repro.core.adaptive import (
    AdaptiveState,
    combine,
    init_adaptive,
    merge_params,
    split_params,
)
from repro.core.aggregation import fedavg_aggregate, personalized_aggregate
from repro.core.fedstil import FedSTIL
from repro.core.rehearsal import PrototypeMemory
from repro.core.relevance import RelevanceTracker
from repro.core.similarity import (
    SIMILARITY_FNS,
    cosine_similarity,
    euclidean_similarity,
    kl_similarity,
    pairwise_similarity,
)
from repro.core.tying import tying_loss
