"""Knowledge relevance across the spatial-temporal dimension (paper Eq. 5).

The server keeps the last ``k`` rounds of task features for every client.
Relevance between client i's *current* task and client j is the
forgetting-ratio-decayed sum of similarities against j's task history:

    W_ij^(t) = sum_{t'=t-k..t} lambda_f^{t-t'} * S_ij^(t,t')

Rows are normalised over j != i so Eq. (6) is a convex combination of
neighbour parameters (self-knowledge already lives in A_c / alpha_c).

Two server implementations share this module:

  * ``backend="loop"`` — the original O(C²·k) Python reference, one device
    round-trip per (i, j, age) similarity. Kept as the allclose oracle.
  * batched (default) — histories live in a device-resident ``(C, k, D)``
    ring buffer with a ``(C, k)`` validity mask (``DeviceRingHistory``,
    updated by one batched roll/scatter per round via the tracker's
    ``push_all``; per-client ``push`` falls back to re-stacking the host
    lists) and all-pairs decayed relevance is one ``(C, C·k)`` similarity
    matrix (the Pallas KL kernel for ``metric="kl"``) contracted against
    the decay vector on device. ``backend`` then selects the kernel path
    (``ref`` / ``pallas`` / ``interpret``); ``None`` picks the compiled
    kernel on TPU and the jnp oracle elsewhere.

``decayed_relevance`` is the shared Eq. 4/5 primitive: the on-mesh server
(``launch/fed_round.py``) calls it per-client inside shard_map and the
parameter-server tracker calls it for all clients at once.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.similarity import SIMILARITY_FNS, pairwise_similarity


def decayed_relevance(cur, hist, decay, valid=None, *, metric: str = "kl",
                      backend: Optional[str] = None):
    """Batched Eq. 4/5: decayed all-pairs relevance.

    cur: (N, D) current task features; hist: (C, k, D) per-client task
    histories; decay: (k,) per-slot decay weights (aligned with hist's k
    axis); valid: optional (C, k) {0,1} mask for ragged histories.
    Returns (N, C) *unnormalized* relevance (no diagonal masking).

    ``backend`` selects the KL similarity kernel path only: cosine and
    euclidean have a single jnp implementation (no Pallas kernel) and
    ignore it.
    """
    C, k, D = hist.shape
    flat = hist.reshape(C * k, D)
    if metric == "kl":
        from repro.kernels import ops
        S = ops.kl_similarity(cur, flat, backend=backend)
    else:
        S = pairwise_similarity(cur, flat, metric=metric)
    S = S.reshape(cur.shape[0], C, k)
    if valid is not None:
        S = S * valid[None, :, :]
    return jnp.einsum("nck,k->nc", S, decay.astype(jnp.float32))


def normalize_rows(W: np.ndarray) -> np.ndarray:
    """Row-normalise, leaving all-zero rows (no relevant neighbours) zero."""
    W = np.asarray(W, np.float32)
    rows = W.sum(1, keepdims=True)
    return np.divide(W, rows, out=np.zeros_like(W), where=rows > 0)


@jax.jit
def _ring_push(buf, valid, stale, feats, mask):
    """Batched roll/scatter ring update: age-major shift (most recent at
    age 0) for rows selected by ``mask``; unselected rows are untouched.
    ``stale`` is the per-client rounds-since-last-push counter — pushed
    rows reset to 0, skipped rows age by 1 (the telemetry signal the
    async scheduler's staleness decay will consume)."""
    rolled = jnp.roll(buf, 1, axis=1).at[:, 0].set(feats)
    rvalid = jnp.roll(valid, 1, axis=1).at[:, 0].set(1.0)
    keep = mask > 0
    buf = jnp.where(keep[:, None, None], rolled, buf)
    valid = jnp.where(keep[:, None], rvalid, valid)
    stale = jnp.where(keep, jnp.zeros_like(stale), stale + 1.0)
    return buf, valid, stale


def ring_relevance(buf, valid, *, forgetting_ratio: float, metric: str = "kl",
                   backend: Optional[str] = None):
    """Unnormalized (C, C) decayed relevance over a ring-buffer history:
    each client's latest feature (age 0) vs every history, rows without a
    current feature zeroed. Diagonal NOT masked — the fused aggregate
    kernel owns that. jit-traceable; shared by ``DeviceRingHistory`` and
    the stacked FedSTIL server program."""
    k = buf.shape[1]
    decay = forgetting_ratio ** jnp.arange(k, dtype=jnp.float32)
    W = decayed_relevance(buf[:, 0], buf, decay, valid,
                          metric=metric, backend=backend)
    return W * valid[:, 0][:, None]


@dataclasses.dataclass
class DeviceRingHistory:
    """Device-resident (C, k, D) task-feature history (age-major: most
    recent at age 0) with a (C, k) validity mask.

    The layout is identical to ``RelevanceTracker.stacked_history`` — which
    stays as the host-list oracle — but the buffer lives on device between
    rounds and is updated by one batched roll/scatter per round instead of
    being re-stacked from Python lists.
    """

    n_clients: int
    history_len: int
    dim: int

    def __post_init__(self):
        C, k, D = self.n_clients, self.history_len, self.dim
        self.buf = jnp.zeros((C, k, D), jnp.float32)
        self.valid = jnp.zeros((C, k), jnp.float32)
        # rounds since each client last pushed (telemetry + async-scheduler
        # staleness signal); rides the same ring-push program
        self.stale = jnp.zeros((C,), jnp.float32)

    def push_all(self, feats, mask=None):
        """feats: (C, D) this round's task features; mask: optional (C,)
        {0,1} participation (rows with 0 keep their history untouched)."""
        feats = jnp.asarray(feats, jnp.float32)
        if mask is None:
            mask = jnp.ones((self.n_clients,), jnp.float32)
        self.buf, self.valid, self.stale = _ring_push(
            self.buf, self.valid, self.stale, feats,
            jnp.asarray(mask, jnp.float32))

    def place(self, mesh):
        """Shard the ring's client rows over the mesh's "data" axis (the
        engine="sharded" layout from ``sharding.specs``): the roll/scatter
        push and Eq. 4/5 relevance then run as SPMD programs with each
        device updating only its resident client block. n_clients must
        already be the mesh-padded Cp."""
        from repro.sharding import specs as shard_specs
        sh = jax.sharding.NamedSharding
        self.buf = jax.device_put(
            self.buf, sh(mesh, shard_specs.client_row_spec(3)))
        self.valid = jax.device_put(
            self.valid, sh(mesh, shard_specs.client_row_spec(2)))
        self.stale = jax.device_put(
            self.stale, sh(mesh, shard_specs.client_row_spec(1)))

    def stacked(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.buf, self.valid

    def raw_relevance(self, *, forgetting_ratio: float, metric: str = "kl",
                      backend: Optional[str] = None) -> jnp.ndarray:
        """See ``ring_relevance`` (the shared Eq. 4/5 ring primitive)."""
        return ring_relevance(self.buf, self.valid,
                              forgetting_ratio=forgetting_ratio,
                              metric=metric, backend=backend)


@dataclasses.dataclass
class RelevanceTracker:
    n_clients: int
    history_len: int = 6          # k in Eq. (5)
    forgetting_ratio: float = 0.5  # lambda_f
    metric: str = "kl"
    # "loop" = Python reference; otherwise the kernel backend for the
    # batched path (kl only — cosine/euclidean have no kernel and use
    # their single jnp implementation regardless)
    backend: Optional[str] = None

    def __post_init__(self):
        # history[c] = list of task features, most recent last (the oracle
        # layout); the device ring mirrors it once push_all is used
        self.history: List[list] = [[] for _ in range(self.n_clients)]
        self._ring: Optional[DeviceRingHistory] = None
        self._ring_dirty = False   # host lists diverged (per-client push)

    def push(self, client: int, task_feature):
        h = self.history[client]
        h.append(np.asarray(task_feature, np.float32))
        if len(h) > self.history_len:
            h.pop(0)
        self._ring_dirty = True

    def push_all(self, feats, mask=None):
        """Batched push: feats (C, D) for all clients at once, mask an
        optional (C,) participation indicator. Updates the device-resident
        ring with one roll/scatter AND the host lists (the loop oracle), so
        ``relevance()`` no longer re-stacks from host every round."""
        feats = np.asarray(feats, np.float32)
        if mask is None:
            mask = np.ones((self.n_clients,), np.float32)
        mask = np.asarray(mask, np.float32)
        if self._ring is None or self._ring_dirty:
            # (re)build the ring from the oracle lists, then go resident
            self._ring = DeviceRingHistory(self.n_clients, self.history_len,
                                           feats.shape[-1])
            stacked = self.stacked_history()
            if stacked is not None:
                self._ring.buf = jnp.asarray(stacked[0])
                self._ring.valid = jnp.asarray(stacked[1])
            self._ring_dirty = False
        self._ring.push_all(feats, mask)
        for c in range(self.n_clients):
            if mask[c] > 0:
                h = self.history[c]
                h.append(feats[c].copy())
                if len(h) > self.history_len:
                    h.pop(0)

    def stacked_history(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Dense (C, k, D) age-major history (most recent at age 0) plus a
        (C, k) validity mask; None while every history is empty."""
        C, k = self.n_clients, self.history_len
        D = next((h[-1].shape[-1] for h in self.history if h), None)
        if D is None:
            return None
        dense = np.zeros((C, k, D), np.float32)
        valid = np.zeros((C, k), np.float32)
        for j, h in enumerate(self.history):
            for age, feat in enumerate(reversed(h)):
                if age >= k:
                    break
                dense[j, age] = feat
                valid[j, age] = 1.0
        return dense, valid

    def relevance(self, backend: Optional[str] = None) -> np.ndarray:
        """W (C, C): row i = normalized relevance of neighbours j for i."""
        b = backend if backend is not None else self.backend
        if b == "loop":
            return self._relevance_loop()
        return self._relevance_batched(b)

    def _relevance_batched(self, backend: Optional[str]) -> np.ndarray:
        C, k = self.n_clients, self.history_len
        if self._ring is not None and not self._ring_dirty:
            # device-resident path: no host re-stack, one device program
            dense, valid = self._ring.stacked()
        else:
            stacked = self.stacked_history()
            if stacked is None:
                return np.zeros((C, C), np.float32)
            dense, valid = jnp.asarray(stacked[0]), jnp.asarray(stacked[1])
        cur = dense[:, 0]                     # each client's latest feature
        has_cur = valid[:, 0]                 # rows without history stay 0
        decay = self.forgetting_ratio ** np.arange(k, dtype=np.float32)
        W = decayed_relevance(cur, dense, jnp.asarray(decay), valid,
                              metric=self.metric, backend=backend)
        W = W * has_cur[:, None] * (1.0 - jnp.eye(C, dtype=jnp.float32))
        return normalize_rows(np.asarray(W))

    def _relevance_loop(self) -> np.ndarray:
        """Reference O(C²·k) implementation (one device trip per pair)."""
        C = self.n_clients
        fn = SIMILARITY_FNS[self.metric]
        W = np.zeros((C, C), np.float32)
        for i in range(C):
            if not self.history[i]:
                continue
            cur = jnp.asarray(self.history[i][-1])
            for j in range(C):
                if i == j or not self.history[j]:
                    continue
                acc, hj = 0.0, self.history[j]
                for age, feat in enumerate(reversed(hj)):
                    if age >= self.history_len:
                        break
                    s = float(fn(cur, jnp.asarray(feat)))
                    acc += (self.forgetting_ratio ** age) * s
                W[i, j] = acc
        return normalize_rows(W)
