"""Knowledge relevance across the spatial-temporal dimension (paper Eq. 5).

The server keeps the last ``k`` rounds of task features for every client.
Relevance between client i's *current* task and client j is the
forgetting-ratio-decayed sum of similarities against j's task history:

    W_ij^(t) = sum_{t'=t-k..t} lambda_f^{t-t'} * S_ij^(t,t')

Rows are normalised over j != i so Eq. (6) is a convex combination of
neighbour parameters (self-knowledge already lives in A_c / alpha_c).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.similarity import SIMILARITY_FNS


@dataclasses.dataclass
class RelevanceTracker:
    n_clients: int
    history_len: int = 6          # k in Eq. (5)
    forgetting_ratio: float = 0.5  # lambda_f
    metric: str = "kl"

    def __post_init__(self):
        # history[c] = list of task features, most recent last
        self.history: List[list] = [[] for _ in range(self.n_clients)]

    def push(self, client: int, task_feature):
        h = self.history[client]
        h.append(np.asarray(task_feature, np.float32))
        if len(h) > self.history_len:
            h.pop(0)

    def relevance(self) -> np.ndarray:
        """W (C, C): row i = normalized relevance of neighbours j for i."""
        C = self.n_clients
        fn = SIMILARITY_FNS[self.metric]
        W = np.zeros((C, C), np.float32)
        for i in range(C):
            if not self.history[i]:
                continue
            cur = jnp.asarray(self.history[i][-1])
            for j in range(C):
                if i == j or not self.history[j]:
                    continue
                acc, hj = 0.0, self.history[j]
                for age, feat in enumerate(reversed(hj)):
                    if age >= self.history_len:
                        break
                    s = float(fn(cur, jnp.asarray(feat)))
                    acc += (self.forgetting_ratio ** age) * s
                W[i, j] = acc
        # row-normalise over neighbours
        rows = W.sum(1, keepdims=True)
        W = np.divide(W, rows, out=np.zeros_like(W), where=rows > 0)
        return W
