"""Personalized model aggregation on the parameter server (paper Eq. 6):

    B_i = sum_{j in C\\i} W_ij^(t) * theta_j

On the TPU mesh this is a client-axis weighted matmul over the flattened
adaptive pytrees — see kernels/relevance_aggregate.py for the Pallas
version; this module is the reference implementation that also runs the
edge-scale benchmarks on CPU.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def stack_thetas(thetas: Sequence):
    """List of C identical pytrees -> single pytree with leading C dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *thetas)


def unstack(tree, n: int):
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def personalized_aggregate(thetas: Sequence, W) -> List:
    """B_i = sum_j W[i, j] * theta_j for every client i.

    thetas: length-C list of adaptive pytrees; W: (C, C) with zero diagonal.
    Returns a length-C list of base pytrees B_i.
    """
    W = jnp.asarray(W, jnp.float32)
    stacked = stack_thetas(thetas)                     # leaves (C, ...)
    agg = jax.tree.map(
        lambda x: jnp.einsum(
            "ij,j...->i...", W, x.astype(jnp.float32)).astype(x.dtype),
        stacked)
    return unstack(agg, W.shape[0])


def fedavg_aggregate(thetas: Sequence, weights=None):
    """Uniform (or sample-count-weighted) FedAvg mean."""
    C = len(thetas)
    if weights is None:
        w = np.full((C,), 1.0 / C, np.float32)
    else:
        w = np.asarray(weights, np.float32)
        w = w / w.sum()
    stacked = stack_thetas(thetas)
    return jax.tree.map(
        lambda x: jnp.einsum(
            "j,j...->...", jnp.asarray(w), x.astype(jnp.float32)).astype(x.dtype),
        stacked)
