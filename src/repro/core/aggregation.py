"""Personalized model aggregation on the parameter server (paper Eq. 6):

    B_i = sum_{j in C\\i} W_ij^(t) * theta_j

The production path flattens the C adaptive pytrees to one (C, P) matrix
(``common.pytree.tree_stack_flatten``) and runs the single W @ Θ matmul
through ``kernels.ops.relevance_aggregate`` — the Pallas kernel on TPU, the
jnp oracle elsewhere, interpret mode for kernel-correctness tests. The
original per-leaf einsum is retained as ``backend="loop"``, the allclose
reference.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import (tree_stack, tree_stack_flatten,
                                 tree_unstack, tree_unstack_unflatten)
from repro.kernels import ops


def stack_thetas(thetas: Sequence):
    """List of C identical pytrees -> single pytree with leading C dim."""
    return tree_stack(thetas)


def unstack(tree, n: int):
    return tree_unstack(tree, n)


def personalized_aggregate(thetas: Sequence, W, *,
                           backend: Optional[str] = None) -> List:
    """B_i = sum_j W[i, j] * theta_j.

    thetas: length-C list of adaptive pytrees; W: (R, C) relevance rows
    (R = C with zero diagonal in the classic all-clients round; R < C when
    the server skips zero rows). Returns a length-R list of base pytrees.

    backend: "loop" = per-leaf einsum reference; otherwise forwarded to
    ``ops.relevance_aggregate`` over the flattened (C, P) stack (None =
    detected backend: pallas on TPU, jnp oracle elsewhere).
    """
    W = jnp.asarray(W, jnp.float32)
    if backend == "loop":
        stacked = stack_thetas(thetas)                 # leaves (C, ...)
        agg = jax.tree.map(
            lambda x: jnp.einsum(
                "ij,j...->i...", W, x.astype(jnp.float32)).astype(x.dtype),
            stacked)
        return unstack(agg, W.shape[0])
    flat, meta = tree_stack_flatten(thetas)            # (C, P)
    agg = ops.relevance_aggregate(W, flat, backend=backend)
    return tree_unstack_unflatten(agg, meta)


def fedavg_aggregate(thetas: Sequence, weights=None):
    """Uniform (or sample-count-weighted) FedAvg mean."""
    C = len(thetas)
    if weights is None:
        w = np.full((C,), 1.0 / C, np.float32)
    else:
        w = np.asarray(weights, np.float32)
        w = w / w.sum()
    stacked = stack_thetas(thetas)
    return jax.tree.map(
        lambda x: jnp.einsum(
            "j,j...->...", jnp.asarray(w), x.astype(jnp.float32)).astype(x.dtype),
        stacked)
