"""Edge-scale ReID model: frozen extraction layers + adaptive layers.

This is the paper's deployment model at benchmark scale: the backbone trunk
("extraction layers" G_c, initialized from pre-trained weights and frozen)
encodes raw images into compact prototypes (Eq. 1); the "adaptive layers"
(last block + classifier in the paper; an MLP block + bias-free classifier
here, matching the paper's modified-ResNet head: BN after the representation,
no classifier bias) are what FedSTIL decomposes as theta = B ⊙ alpha + A.

For the assigned large architectures the same split is realised as
(transformer trunk | last block + head) — see repro/core/adaptive.split_params.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp



@dataclasses.dataclass(frozen=True)
class EdgeModelConfig:
    img_dim: int = 256         # stub "image" dimensionality (synthetic data)
    proto_dim: int = 128       # prototype size (extraction-layer output)
    hidden: int = 128          # adaptive-layer hidden
    feat_dim: int = 64         # retrieval feature size
    n_classes: int = 512       # global identity space


def init_extraction(key, cfg: EdgeModelConfig):
    """Frozen G_c: simulates the pre-trained ResNet trunk."""
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / jnp.sqrt(cfg.img_dim)
    s2 = 1.0 / jnp.sqrt(cfg.proto_dim)
    return {
        "w1": jax.random.normal(k1, (cfg.img_dim, cfg.proto_dim)) * s1,
        "w2": jax.random.normal(k2, (cfg.proto_dim, cfg.proto_dim)) * s2,
    }


def extract_prototypes(g_params, images):
    """Eq. (1): P = G(X). images: (N, img_dim) -> (N, proto_dim)."""
    h = jnp.tanh(images @ g_params["w1"])
    return jnp.tanh(h @ g_params["w2"])


def init_adaptive_layers(key, cfg: EdgeModelConfig):
    """Trainable F_c (decomposed by FedSTIL into B ⊙ alpha + A)."""
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = 1.0 / jnp.sqrt(cfg.proto_dim)
    s2 = 1.0 / jnp.sqrt(cfg.hidden)
    return {
        "l1": {"w": jax.random.normal(k1, (cfg.proto_dim, cfg.hidden)) * s1,
               "b": jnp.zeros((cfg.hidden,))},
        "l2": {"w": jax.random.normal(k2, (cfg.hidden, cfg.feat_dim)) * s2,
               "b": jnp.zeros((cfg.feat_dim,))},
        "bn": {"scale": jnp.ones((cfg.feat_dim,)),
               "bias": jnp.zeros((cfg.feat_dim,))},
        # bias-free classifier (paper: "bias of the classifier is removed")
        "head": {"w": jax.random.normal(k3, (cfg.feat_dim, cfg.n_classes))
                 * (1.0 / jnp.sqrt(cfg.feat_dim))},
    }


def adaptive_pre_bn(theta, protos):
    """The head up to (not including) BN: protos (N, D) -> (N, feat_dim)."""
    h = jax.nn.relu(protos @ theta["l1"]["w"] + theta["l1"]["b"])
    return h @ theta["l2"]["w"] + theta["l2"]["b"]


def adaptive_bn_stats(f, mask):
    """BN statistics (mu, sd) of a pre-BN batch over ``mask``-valid rows
    only (zero-padded rows contribute nothing). f: (N, feat_dim);
    mask: (N,) 1.0 = valid. Returns (feat_dim,) each."""
    m = mask.astype(f.dtype)[:, None]
    n = jnp.maximum(jnp.sum(m), 1.0)
    mu = jnp.sum(f * m, 0) / n
    sd = jnp.sqrt(jnp.sum(jnp.square(f - mu[None, :]) * m, 0) / n) + 1e-5
    return mu, sd


def adaptive_bn_apply(theta, f, mu, sd):
    """BN affine with the given statistics: (N, feat_dim) -> features."""
    return (f - mu) / sd * theta["bn"]["scale"] + theta["bn"]["bias"]


def adaptive_forward_masked(theta, protos, mask):
    """prototypes -> (retrieval features, class logits) over a padded
    batch: the BN-style statistics (paper adds BN after the representation)
    are computed over ``mask``-valid rows only, so zero-padded rows
    contribute nothing. protos: (N, D); mask: (N,) 1.0 = valid."""
    f = adaptive_pre_bn(theta, protos)
    mu, sd = adaptive_bn_stats(f, mask)
    fn = adaptive_bn_apply(theta, f, mu, sd)
    logits = fn @ theta["head"]["w"]
    return fn, logits


def adaptive_forward_frozen(theta, protos, mu, sd):
    """Inference-mode featurization with FROZEN BN statistics: the serving
    forward. ``mu``/``sd`` come from ``adaptive_bn_stats`` over the client's
    resident gallery at index-refresh time, so a query's feature does not
    depend on whichever batch it was coalesced into (batch-composition
    invariance — the contract the continuous batcher relies on). Returns
    features only: the classifier head is dead weight at retrieval time."""
    return adaptive_bn_apply(theta, adaptive_pre_bn(theta, protos), mu, sd)


def adaptive_forward(theta, protos):
    """prototypes -> (retrieval features, class logits)."""
    return adaptive_forward_masked(
        theta, protos, jnp.ones((protos.shape[0],), jnp.float32))


def ce_loss(theta, protos, labels):
    feats, logits = adaptive_forward(theta, protos)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
    return jnp.mean(nll)
