"""Task-similarity functions Π(·,·) over task features (paper Eq. 4).

Task features are mean prototypes (Eq. 3). The paper adopts KL divergence
(Table VI shows it beats cosine/euclidean); we expose all three. Similarities
are mapped to [0, 1]-ish relevance scores (higher = more relevant) so that
Eq. (5)'s exponentially-decayed accumulation and Eq. (6)'s weighted
aggregation receive *weights*, not divergences.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _as_dist(x, axis=-1):
    """Softmax-normalize a task feature into a distribution (fp64-safe)."""
    x = x.astype(jnp.float32)
    return jax.nn.softmax(x, axis=axis)


def kl_similarity(a, b):
    """exp(-KL(a||b)) with softmax-normalised features. a,b: (..., D)."""
    p, q = _as_dist(a), _as_dist(b)
    kl = jnp.sum(p * (jnp.log(p + 1e-12) - jnp.log(q + 1e-12)), -1)
    return jnp.exp(-kl)


def cosine_similarity(a, b):
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    num = jnp.sum(a * b, -1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + 1e-12
    return 0.5 * (1.0 + num / den)


def euclidean_similarity(a, b):
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    d = jnp.linalg.norm(a - b, axis=-1)
    return jnp.exp(-d)


SIMILARITY_FNS = {
    "kl": kl_similarity,
    "cosine": cosine_similarity,
    "euclidean": euclidean_similarity,
}


def pairwise_similarity(feats_a, feats_b, metric: str = "kl"):
    """All-pairs similarity: (N, D) x (M, D) -> (N, M)."""
    fn = SIMILARITY_FNS[metric]
    return jax.vmap(lambda fa: jax.vmap(lambda fb: fn(fa, fb))(feats_b))(feats_a)
