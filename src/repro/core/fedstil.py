"""FedSTIL — the paper's method (Algorithm 1), as a Strategy.

Per round t, per client c:
  1. prototypes P_c^t = G_c(D_c^t) arrive (extraction layers frozen);
  2. server receives only the task feature (mean prototype, Eq. 3);
  3. server computes KL task similarity (Eq. 4), decayed knowledge
     relevance W (Eq. 5), and the personalized base B_c = Σ W_cj θ_j (Eq. 6);
  4. client sets θ_c = B_c ⊙ α_c + A_c (Eq. 2) and trains (α_c, A_c) on a
     mix of current prototypes and rehearsal samples, with parameter tying;
  5. client stores nearest-mean exemplar prototypes; uploads θ_c.

Ablation switches (Table III): ``st_integration``, ``rehearsal``, ``tying``.
Distance metric switch (Table VI): ``metric`` ∈ {kl, cosine, euclidean}.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.precision import to_bf16, to_f32
from repro.common.pytree import (tree_bytes, tree_flatten_stacked,
                                 tree_unflatten_stacked)
from repro.core import edge_model as EM
from repro.core.adaptive import combine, init_adaptive
from repro.core.aggregation import personalized_aggregate
from repro.core.rehearsal import PrototypeMemory
from repro.core.relevance import (DeviceRingHistory, RelevanceTracker,
                                  normalize_rows)
from repro.core.tying import tying_loss
from repro.federated.base import ClientState, Strategy
from repro.kernels import ops
from repro.obs import trace as obs


def sharded_fused_aggregate(w, thetas, mesh, *, backend=None):
    """The engine's jit-with-NamedSharding Eq. 5→6 aggregate over a real
    mesh — layouts come from ``sharding.specs.stacked_aggregate_specs``
    (the single source of truth; the old ``launch/fed_round`` demo that
    re-derived them privately is gone).

    Θ (C, P) rows live on the "data" axis, W contracts its columns against
    them, and the output base matrix keeps the client-row sharding so the
    per-device footprint stays C/d × P at every stage. GSPMD lowers the
    contraction to per-shard partial products plus one reduce over "data"
    (the relevance normalizer inside the kernel is the one psum). Values
    are bit-identical to ``ops.fused_relevance_aggregate`` on one device —
    tier-1 asserts it.
    """
    from jax.sharding import NamedSharding
    from repro.sharding.specs import stacked_aggregate_specs
    sp = stacked_aggregate_specs()
    key = (mesh, backend)
    if key not in _SHARDED_AGG_CACHE:
        _SHARDED_AGG_CACHE[key] = jax.jit(
            functools.partial(ops.fused_relevance_aggregate,
                              backend=backend),
            out_shardings=(NamedSharding(mesh, sp["out"]),
                           NamedSharding(mesh, sp["wn"])))
    w = jax.device_put(jnp.asarray(w), NamedSharding(mesh, sp["w"]))
    thetas = jax.device_put(jnp.asarray(thetas),
                            NamedSharding(mesh, sp["thetas"]))
    return _SHARDED_AGG_CACHE[key](w, thetas)


_SHARDED_AGG_CACHE: dict = {}


class FedSTIL(Strategy):
    name = "fedstil"
    uses_server = True
    supports_stacked = True

    def __init__(self, cfg, *, n_clients=5, metric="kl", forgetting_ratio=0.5,
                 history_len=6, memory_size=2000, per_identity=8,
                 lam_tie=1e-4, st_integration=True, rehearsal=True,
                 tying=True, server_backend=None, wire_dtype="bfloat16",
                 **kw):
        super().__init__(cfg, **kw)
        # sharded-engine precision rule (common/precision.py): the (C, P)
        # flatten that crosses the shard boundary is emitted in wire_dtype
        # (bf16 default — half the resident/resharded bytes) and upcast to
        # f32 inside the aggregate; "float32" turns the cast off for
        # bit-tight parity runs. Optimizer/BN state is always f32.
        self.wire_dtype = wire_dtype
        self.n_clients = n_clients
        self.lam_tie = lam_tie
        self.st_integration = st_integration
        self.use_rehearsal = rehearsal
        self.use_tying = tying
        self.memory_size = memory_size
        self.per_identity = per_identity
        # server_backend: "loop" reference or a kernel backend for both the
        # batched relevance and the flattened Eq. 6 aggregation
        self.server_backend = server_backend
        self.tracker = RelevanceTracker(
            n_clients, history_len=history_len,
            forgetting_ratio=forgetting_ratio, metric=metric,
            backend=server_backend)
        # stacked engine: its own device-resident history (the host tracker
        # stays untouched so engine="host" remains the allclose oracle)
        self._ring: Optional[DeviceRingHistory] = None
        self.last_W: Optional[np.ndarray] = None

    # ---- decomposition -------------------------------------------------------
    def init_client(self, key):
        theta0 = EM.init_adaptive_layers(key, self.cfg)
        ad = init_adaptive(theta0)
        st = ClientState(theta=ad.trainable())
        st.extras["reg_B"] = ad.B
        st.extras["reg_prev_theta"] = theta0
        st.extras["memory"] = PrototypeMemory(
            capacity=self.memory_size, per_identity=self.per_identity)
        return st

    def make_theta(self, trainable, extras):
        return combine(extras["reg_B"], trainable["alpha"], trainable["A"])

    def regularizer(self, trainable, extras):
        if not self.use_tying:
            return 0.0
        theta = self.make_theta(trainable, extras)
        return tying_loss(theta, extras["reg_prev_theta"], lam_l1=self.lam_tie)

    def _eval_theta(self, state):
        return self.make_theta(state.theta, state.extras)

    def eval_theta_stacked(self, stacked):
        # theta = B ⊙ alpha + A leaf-wise: the stacked C dim passes through
        return combine(stacked.extras["reg_B"], stacked.trainable["alpha"],
                       stacked.trainable["A"])

    # ---- local round ---------------------------------------------------------
    def local_train(self, client, state, protos, labels, rnd, **_):
        rehearsal = None
        mem: PrototypeMemory = state.extras["memory"]
        if self.use_rehearsal and len(mem):
            rehearsal = mem.sample(self.rng, self.batch)
        state, _ = self._run_epochs(state, protos, labels, rehearsal)

        theta = self._eval_theta(state)
        state.extras["reg_prev_theta"] = theta

        # store exemplar prototypes (nearest-mean, Fig. 4)
        if self.use_rehearsal:
            outputs, _ = EM.adaptive_forward(theta, jnp.asarray(protos))
            mem.add_task(protos, labels, np.asarray(outputs), task_id=rnd)

        # upload: adaptive-layer params + the tiny task feature (Eq. 3)
        task_feature = np.asarray(protos, np.float32).mean(0)
        return state, {"theta": theta, "task_feature": task_feature}

    # ---- server round (spatial-temporal integration) -------------------------
    def server_round(self, rnd, uploads):
        if not self.st_integration or not uploads:
            return {}
        clients = sorted(uploads)
        # one batched roll/scatter into the tracker's device-resident ring
        # (the host lists stay in sync as the loop oracle)
        feats = np.zeros((self.n_clients,
                          np.asarray(uploads[clients[0]]["task_feature"]).shape[-1]),
                         np.float32)
        mask = np.zeros((self.n_clients,), np.float32)
        for c in clients:
            feats[c] = uploads[c]["task_feature"]
            mask[c] = 1.0
        self.tracker.push_all(feats, mask)
        W = self.tracker.relevance()
        self.last_W = W
        # aggregate only rows with relevant neighbours: round 0 (and any
        # client whose neighbours have no history yet) is an all-zero row —
        # skipping it avoids wasted matmul rows and keeps NaNs out entirely.
        # Under partial participation the subset rows are renormalized so
        # Eq. 6 stays a convex combination of the neighbours that DID
        # upload (identity when everyone uploads).
        Wc = normalize_rows(W[np.ix_(clients, clients)])
        nz = np.flatnonzero(Wc.sum(1) > 0)
        out = {c: {} for c in clients}   # {} = no relevant neighbours yet
        if nz.size:
            thetas = [uploads[c]["theta"] for c in clients]
            bases = personalized_aggregate(thetas, Wc[nz],
                                           backend=self.server_backend)
            for row, base in zip(nz, bases):
                out[clients[row]] = {"B": base}
        return out

    def apply_dispatch(self, state, dispatch):
        if "B" in dispatch:
            state.extras["reg_B"] = dispatch["B"]
        return state

    # ---- wire-codec payload split --------------------------------------------
    # Uploads are (theta, task feature): theta is the bulk payload the codec
    # compresses; the Eq. 3 task feature is the server's control plane for
    # relevance (Eq. 4/5) and ships verbatim — letting global top-k compete
    # theta entries against it would distort W for a negligible byte win.
    # Dispatches are (B, engine metadata): only B is wire payload.

    def split_upload_for_wire(self, upload):
        return ({"theta": upload["theta"]},
                {"task_feature": upload["task_feature"]})

    def join_upload_from_wire(self, decoded, verbatim):
        return {"theta": decoded["theta"], **verbatim}

    def split_dispatch_for_wire(self, dispatch):
        verbatim = {k: v for k, v in dispatch.items() if k != "B"}
        return {"B": dispatch["B"]}, (verbatim or None)

    def join_dispatch_from_wire(self, decoded, verbatim):
        return {"B": decoded["B"], **(verbatim or {})}

    def storage_bytes(self, state):
        mem: PrototypeMemory = state.extras["memory"]
        return (tree_bytes(state.theta) + tree_bytes(state.extras["reg_B"])
                + mem.size_bytes)

    # ---- stacked (device-resident) engine ------------------------------------
    def _gather_rehearsal(self, stacked, c):
        if not self.use_rehearsal:
            return None
        mem: PrototypeMemory = stacked.host["memory"][c]
        if not len(mem):
            return None
        return mem.sample(self.rng, self.batch)

    def local_train_stacked(self, stacked, bx, by, protos_list, labels_list,
                            rnd):
        stacked, _ = super().local_train_stacked(stacked, bx, by,
                                                 protos_list, labels_list, rnd)
        # theta = B ⊙ alpha + A for all clients at once (leaf-wise, so the
        # stacked leading dim passes straight through)
        theta = combine(stacked.extras["reg_B"], stacked.trainable["alpha"],
                        stacked.trainable["A"])
        stacked.extras["reg_prev_theta"] = theta

        if self.use_rehearsal:
            # host memories exist only for the C real clients; on a mesh
            # theta carries Cp >= C padded rows, so slice before the vmap
            C = len(protos_list)
            theta_real = jax.tree.map(lambda l: l[:C], theta)
            protos = jnp.asarray(np.stack(protos_list))      # (C, N, D)
            outputs = np.asarray(jax.vmap(
                lambda th, p: EM.adaptive_forward(th, p)[0])(theta_real,
                                                             protos))
            for c, mem in enumerate(stacked.host["memory"]):
                mem.add_task(protos_list[c], labels_list[c], outputs[c],
                             task_id=rnd)

        feats = np.stack([np.asarray(p, np.float32).mean(0)
                          for p in protos_list])
        lead = jax.tree.leaves(theta)[0].shape[0]
        if lead > feats.shape[0]:
            # mesh padding rows: zero features — the validity mask keeps
            # them out of the relevance ring, so the values never matter
            feats = np.concatenate(
                [feats, np.zeros((lead - feats.shape[0], feats.shape[1]),
                                 np.float32)])
        return stacked, {"theta": theta, "task_feature": jnp.asarray(feats)}

    def _stacked_server_fns(self, theta_example):
        """Staged jitted pieces of the stacked server round.

        Deliberately NOT one mega-jit: on CPU, fusing the (C, P) flatten
        into the aggregate defeats XLA's fast GEMM path (measured ~2.5x
        slower at C=100). Each stage is its own device program — ring push
        + Eq. 4/5 relevance (tiny), flatten, the fused normalize+mask
        Eq. 6 kernel (via ops), unflatten — with zero host round-trips
        between them.
        """
        if "stacked_relevance" not in self._jit_cache:
            backend = (None if self.server_backend == "loop"
                       else self.server_backend)
            ratio = self.tracker.forgetting_ratio
            metric = self.tracker.metric

            # the ring buffer/validity/staleness are the round-carried
            # server state: the caller overwrites all three with the
            # returns, so donate them.
            # ``mask`` is the per-client push mask — all-ones on the
            # single-device stacked engine, the client-validity mask on the
            # sharded engine (padding rows must never enter the ring: a
            # zero mask keeps their history invalid, so their W rows AND
            # columns stay zero and the nz machinery leaves them alone).
            # The telemetry mets are (C,)-sized outputs of this same
            # launch — the host only reads them back when a tracer is
            # active (obs.metric is a no-op otherwise).
            @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
            def relevance(buf, valid, stale, feats, mask):
                from repro.core.relevance import _ring_push, ring_relevance
                from repro.obs import metrics as obsm
                buf, valid, stale = _ring_push(buf, valid, stale, feats,
                                               mask)
                W = ring_relevance(buf, valid, forgetting_ratio=ratio,
                                   metric=metric, backend=backend)
                mets = obsm.relevance_metrics(W, valid, stale)
                return buf, valid, stale, W, mets

            _, meta = tree_flatten_stacked(theta_example)   # one eager call
            self._jit_cache["stacked_relevance"] = relevance
            self._jit_cache["stacked_flatten"] = jax.jit(
                lambda th: tree_flatten_stacked(th)[0])
            self._jit_cache["stacked_unflatten"] = jax.jit(
                lambda m: tree_unflatten_stacked(m, meta))
        return (self._jit_cache["stacked_relevance"],
                self._jit_cache["stacked_flatten"],
                self._jit_cache["stacked_unflatten"])

    def _sharded_server_fns(self, theta_example):
        """engine="sharded" variants of the flatten/aggregate stages, built
        once against ``self.mesh``. The relevance stage is shared with the
        stacked engine (jit re-specializes on the sharded ring). Deltas:

          * the flatten emits the wire form — ``to_bf16`` of the (Cp, P)
            matrix (``common/precision.py``): that buffer is what crosses
            the shard boundary into the aggregate, at half the bytes;
          * the aggregate is one jit-with-NamedSharding program that
            upcasts to f32 (``to_f32``), runs the fused Eq. 5→6 kernel,
            and pins B to the client-row sharding from ``sharding.specs``
            so the per-device footprint stays Cp/d × P. The f32→bf16→f32
            pair is the sanctioned wire cast the analysis lints accept.
        """
        if "sharded_aggregate" not in self._jit_cache:
            from jax.sharding import NamedSharding
            from repro.sharding.specs import stacked_aggregate_specs
            backend = (None if self.server_backend == "loop"
                       else self.server_backend)
            sp = stacked_aggregate_specs()
            wire = self.wire_dtype

            def flatten_wire(th):
                flat = tree_flatten_stacked(th)[0]
                return to_bf16(flat) if wire == "bfloat16" else flat

            def aggregate(W, flat):
                return ops.fused_relevance_aggregate(W, to_f32(flat),
                                                     backend=backend)

            self._jit_cache["sharded_flatten_wire"] = jax.jit(flatten_wire)
            self._jit_cache["sharded_aggregate"] = jax.jit(
                aggregate,
                out_shardings=(NamedSharding(self.mesh, sp["out"]),
                               NamedSharding(self.mesh, sp["wn"])))
        return (self._jit_cache["sharded_flatten_wire"],
                self._jit_cache["sharded_aggregate"])

    def server_round_stacked(self, rnd, upload, valid=None):
        """Eq. 4/5 → Eq. 6 as a device-resident program over the ring
        buffer. No host round-trips besides the tiny (C, C) relevance
        readback for ``last_W``. ``valid`` is the sharded engine's (Cp,)
        client-validity mask (None on the single-device stacked engine):
        it gates the ring push, so mesh-padding rows never acquire history
        and their relevance rows/columns stay zero."""
        if not self.st_integration:
            return None
        feats = upload["task_feature"]                       # (C, D)
        C = feats.shape[0]
        if self._ring is None:
            self._ring = DeviceRingHistory(C, self.tracker.history_len,
                                           int(feats.shape[-1]))
            if self.mesh is not None:
                self._ring.place(self.mesh)
        relevance, flatten, unflatten = self._stacked_server_fns(
            upload["theta"])
        backend = (None if self.server_backend == "loop"
                   else self.server_backend)
        mask = (jnp.ones((C,), jnp.float32) if valid is None
                else jnp.asarray(valid, jnp.float32))
        with obs.span("server.relevance", cat="stage", round=rnd) as sp:
            (self._ring.buf, self._ring.valid, self._ring.stale, W_raw,
             mets) = relevance(self._ring.buf, self._ring.valid,
                               self._ring.stale, jnp.asarray(feats), mask)
            sp.sync(W_raw)
        if self.mesh is not None:
            flatten_wire, aggregate = self._sharded_server_fns(
                upload["theta"])
            with obs.span("server.flatten", cat="stage", round=rnd) as sp:
                flat = sp.sync(flatten_wire(upload["theta"]))  # (Cp, P) wire
            with obs.span("server.aggregate", cat="stage", round=rnd) as sp:
                B_flat, Wn = sp.sync(aggregate(W_raw, flat))
        else:
            with obs.span("server.flatten", cat="stage", round=rnd) as sp:
                flat = sp.sync(flatten(upload["theta"]))       # (C, P)
            with obs.span("server.aggregate", cat="stage", round=rnd) as sp:
                B_flat, Wn = sp.sync(ops.fused_relevance_aggregate(
                    W_raw, flat, backend=backend))
        # per-client round observables (staleness, ring fill, W row
        # mass/density) — computed inside the relevance launch above;
        # this is a no-op readback unless a tracer is active
        obs.metric("server.relevance", mets, round=rnd)
        self.last_W = np.asarray(Wn)
        # all-zero rows (no relevant neighbours yet) keep their old base
        nz = jnp.sum(Wn, axis=1) > 0
        with obs.span("server.unflatten", cat="stage", round=rnd) as sp:
            B = sp.sync(unflatten(B_flat))
        return {"B": B, "nz": nz}

    def apply_dispatch_stacked(self, stacked, dispatch):
        nz = dispatch["nz"]
        stacked.extras["reg_B"] = jax.tree.map(
            lambda old, new: jnp.where(
                jnp.reshape(nz, (-1,) + (1,) * (old.ndim - 1)),
                new.astype(old.dtype), old),
            stacked.extras["reg_B"], dispatch["B"])
        return stacked

    def stacked_dispatch_bytes(self, dispatch, n_clients: int) -> int:
        return tree_bytes(dispatch["B"]) // max(n_clients, 1)
