"""FedSTIL — the paper's method (Algorithm 1), as a Strategy.

Per round t, per client c:
  1. prototypes P_c^t = G_c(D_c^t) arrive (extraction layers frozen);
  2. server receives only the task feature (mean prototype, Eq. 3);
  3. server computes KL task similarity (Eq. 4), decayed knowledge
     relevance W (Eq. 5), and the personalized base B_c = Σ W_cj θ_j (Eq. 6);
  4. client sets θ_c = B_c ⊙ α_c + A_c (Eq. 2) and trains (α_c, A_c) on a
     mix of current prototypes and rehearsal samples, with parameter tying;
  5. client stores nearest-mean exemplar prototypes; uploads θ_c.

Ablation switches (Table III): ``st_integration``, ``rehearsal``, ``tying``.
Distance metric switch (Table VI): ``metric`` ∈ {kl, cosine, euclidean}.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_bytes
from repro.core import edge_model as EM
from repro.core.adaptive import AdaptiveState, combine, init_adaptive
from repro.core.aggregation import personalized_aggregate
from repro.core.rehearsal import PrototypeMemory
from repro.core.relevance import RelevanceTracker, normalize_rows
from repro.core.tying import tying_loss
from repro.federated.base import ClientState, Strategy


class FedSTIL(Strategy):
    name = "fedstil"
    uses_server = True

    def __init__(self, cfg, *, n_clients=5, metric="kl", forgetting_ratio=0.5,
                 history_len=6, memory_size=2000, per_identity=8,
                 lam_tie=1e-4, st_integration=True, rehearsal=True,
                 tying=True, server_backend=None, **kw):
        super().__init__(cfg, **kw)
        self.n_clients = n_clients
        self.lam_tie = lam_tie
        self.st_integration = st_integration
        self.use_rehearsal = rehearsal
        self.use_tying = tying
        self.memory_size = memory_size
        self.per_identity = per_identity
        # server_backend: "loop" reference or a kernel backend for both the
        # batched relevance and the flattened Eq. 6 aggregation
        self.server_backend = server_backend
        self.tracker = RelevanceTracker(
            n_clients, history_len=history_len,
            forgetting_ratio=forgetting_ratio, metric=metric,
            backend=server_backend)
        self.last_W: Optional[np.ndarray] = None

    # ---- decomposition -------------------------------------------------------
    def init_client(self, key):
        theta0 = EM.init_adaptive_layers(key, self.cfg)
        ad = init_adaptive(theta0)
        st = ClientState(theta=ad.trainable())
        st.extras["reg_B"] = ad.B
        st.extras["reg_prev_theta"] = theta0
        st.extras["memory"] = PrototypeMemory(
            capacity=self.memory_size, per_identity=self.per_identity)
        return st

    def make_theta(self, trainable, extras):
        return combine(extras["reg_B"], trainable["alpha"], trainable["A"])

    def regularizer(self, trainable, extras):
        if not self.use_tying:
            return 0.0
        theta = self.make_theta(trainable, extras)
        return tying_loss(theta, extras["reg_prev_theta"], lam_l1=self.lam_tie)

    def _eval_theta(self, state):
        return self.make_theta(state.theta, state.extras)

    # ---- local round ---------------------------------------------------------
    def local_train(self, client, state, protos, labels, rnd, **_):
        rehearsal = None
        mem: PrototypeMemory = state.extras["memory"]
        if self.use_rehearsal and len(mem):
            rehearsal = mem.sample(self.rng, self.batch)
        state, _ = self._run_epochs(state, protos, labels, rehearsal)

        theta = self._eval_theta(state)
        state.extras["reg_prev_theta"] = theta

        # store exemplar prototypes (nearest-mean, Fig. 4)
        if self.use_rehearsal:
            outputs, _ = EM.adaptive_forward(theta, jnp.asarray(protos))
            mem.add_task(protos, labels, np.asarray(outputs), task_id=rnd)

        # upload: adaptive-layer params + the tiny task feature (Eq. 3)
        task_feature = np.asarray(protos, np.float32).mean(0)
        return state, {"theta": theta, "task_feature": task_feature}

    # ---- server round (spatial-temporal integration) -------------------------
    def server_round(self, rnd, uploads):
        if not self.st_integration:
            return {}
        clients = sorted(uploads)
        for c in clients:
            self.tracker.push(c, uploads[c]["task_feature"])
        W = self.tracker.relevance()
        self.last_W = W
        # aggregate only rows with relevant neighbours: round 0 (and any
        # client whose neighbours have no history yet) is an all-zero row —
        # skipping it avoids wasted matmul rows and keeps NaNs out entirely.
        # Under partial participation the subset rows are renormalized so
        # Eq. 6 stays a convex combination of the neighbours that DID
        # upload (identity when everyone uploads).
        Wc = normalize_rows(W[np.ix_(clients, clients)])
        nz = np.flatnonzero(Wc.sum(1) > 0)
        out = {c: {} for c in clients}   # {} = no relevant neighbours yet
        if nz.size:
            thetas = [uploads[c]["theta"] for c in clients]
            bases = personalized_aggregate(thetas, Wc[nz],
                                           backend=self.server_backend)
            for row, base in zip(nz, bases):
                out[clients[row]] = {"B": base}
        return out

    def apply_dispatch(self, state, dispatch):
        if "B" in dispatch:
            state.extras["reg_B"] = dispatch["B"]
        return state

    def storage_bytes(self, state):
        mem: PrototypeMemory = state.extras["memory"]
        return (tree_bytes(state.theta) + tree_bytes(state.extras["reg_B"])
                + mem.size_bytes)
