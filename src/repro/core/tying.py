"""Parameter tying regularization (paper §IV-C, ablated in Table III).

All parameter *changes* are summarised into a penalty so that models fit new
tasks with minimal, sparse movement of prior knowledge:

    L_tie = lambda_tie * sum |theta - theta_prev|_1  (+ l2 * |A|_2^2 sparsity)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_sub


def tying_loss(theta, theta_prev, lam_l1: float = 1e-4, lam_l2: float = 0.0):
    diff = tree_sub(theta, theta_prev)
    l1 = sum(jnp.sum(jnp.abs(d)) for d in jax.tree.leaves(diff))
    loss = lam_l1 * l1
    if lam_l2:
        l2 = sum(jnp.sum(jnp.square(d)) for d in jax.tree.leaves(diff))
        loss = loss + lam_l2 * l2
    return loss
