"""FedSTIL adaptive-layer parameterization (paper Eq. 2):

    theta_c = B_c ⊙ alpha_c + A_c

``B_c`` carries global spatial-temporal knowledge (dispatched by the server),
``alpha_c`` is a learnable attention that selects the task-specific slice of
it, and ``A_c`` is the locally-learnt residual. Locally trainable parameters
are (alpha_c, A_c); B_c is set by the server each round.

This module is model-agnostic: it operates on any pytree of adaptive-layer
parameters (the MLP edge model in the paper benchmarks, or the last
transformer block + head of any assigned architecture).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class AdaptiveState:
    """Per-client decomposed adaptive parameters."""

    B: Any          # base (server-provided spatial-temporal knowledge)
    alpha: Any      # attention over B (same structure)
    A: Any          # local residual (same structure)

    def theta(self):
        return combine(self.B, self.alpha, self.A)

    def trainable(self):
        return {"alpha": self.alpha, "A": self.A}

    def with_trainable(self, t):
        return AdaptiveState(B=self.B, alpha=t["alpha"], A=t["A"])

    def with_base(self, B):
        return AdaptiveState(B=B, alpha=self.alpha, A=self.A)


def combine(B, alpha, A):
    """theta = B ⊙ alpha + A, leaf-wise (paper Eq. 2).

    The TPU hot-path version of this is kernels/adaptive_combine.py; this is
    the pure-jnp form used in HLO lowering and on CPU.
    """
    return jax.tree.map(lambda b, al, a: b * al + a, B, alpha, A)


def init_adaptive(theta0) -> AdaptiveState:
    """Start with theta == theta0 (pretrained): B=theta0, alpha=1, A=0."""
    return AdaptiveState(
        B=theta0,
        alpha=jax.tree.map(jnp.ones_like, theta0),
        A=jax.tree.map(jnp.zeros_like, theta0),
    )


# ---------------------------------------------------------------------------
# model-level split: which sub-pytree of a full model is "adaptive"
# ---------------------------------------------------------------------------

_ADAPTIVE_KEYS = ("adaptive_layers", "shared_attn", "head", "final_norm")


def split_params(cfg: ModelConfig, params):
    """(frozen extraction layers, adaptive layers) per DESIGN.md §3."""
    adaptive = {k: params[k] for k in _ADAPTIVE_KEYS if k in params}
    frozen = {k: v for k, v in params.items() if k not in adaptive}
    return frozen, adaptive


def merge_params(frozen, adaptive):
    out = dict(frozen)
    out.update(adaptive)
    return out
