"""Prototype rehearsal memory (paper §IV-A, Fig. 4).

Nearest-mean-of-exemplars (iCaRL-style) selection *in prototype space*:
when a task arrives, run its prototypes through the adaptive layers, compute
the per-identity mean of the outputs, and store the prototypes whose outputs
are closest to their identity's mean. Bounded memory, FIFO eviction across
tasks (oldest task's exemplars shrink first), replayed during training.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class PrototypeMemory:
    capacity: int                      # max stored prototypes
    per_identity: int = 8              # exemplars per identity per task

    def __post_init__(self):
        self.protos: Optional[np.ndarray] = None   # (N, D)
        self.labels: Optional[np.ndarray] = None   # (N,)
        self.task_ids: Optional[np.ndarray] = None

    def __len__(self):
        return 0 if self.protos is None else len(self.protos)

    @property
    def size_bytes(self) -> int:
        return 0 if self.protos is None else self.protos.nbytes + self.labels.nbytes

    def add_task(self, protos, labels, outputs, task_id: int):
        """Select nearest-mean exemplars of a new task and store them.

        protos: (N, D) prototypes; outputs: (N, F) adaptive-layer outputs
        used for the mean-center distance; labels: (N,) identity ids.
        """
        protos = np.asarray(protos)
        labels = np.asarray(labels)
        outputs = np.asarray(outputs, np.float32)
        keep_idx: List[int] = []
        for ident in np.unique(labels):
            idx = np.nonzero(labels == ident)[0]
            center = outputs[idx].mean(0)
            d = np.linalg.norm(outputs[idx] - center, axis=1)
            nearest = idx[np.argsort(d)[: self.per_identity]]
            keep_idx.extend(nearest.tolist())
        keep_idx = np.asarray(keep_idx, np.int64)

        new_p = protos[keep_idx]
        new_l = labels[keep_idx]
        new_t = np.full((len(keep_idx),), task_id, np.int64)
        if self.protos is None:
            self.protos, self.labels, self.task_ids = new_p, new_l, new_t
        else:
            self.protos = np.concatenate([self.protos, new_p])
            self.labels = np.concatenate([self.labels, new_l])
            self.task_ids = np.concatenate([self.task_ids, new_t])
        self._evict()

    def _evict(self):
        """Shrink oldest tasks first until under capacity."""
        while len(self) > self.capacity:
            oldest = self.task_ids.min()
            idx = np.nonzero(self.task_ids == oldest)[0]
            n_over = len(self) - self.capacity
            drop = idx[: min(n_over, len(idx))]
            mask = np.ones(len(self), bool)
            mask[drop] = False
            self.protos = self.protos[mask]
            self.labels = self.labels[mask]
            self.task_ids = self.task_ids[mask]
            if mask.all():   # safety
                break

    def sample(self, rng: np.random.Generator, n: int):
        """Sample up to n stored prototypes for rehearsal."""
        if self.protos is None or len(self) == 0 or n <= 0:
            return None
        idx = rng.choice(len(self), size=min(n, len(self)), replace=False)
        return self.protos[idx], self.labels[idx]
