"""Federated lifelong simulation driver (paper §V experimental protocol).

C edge clients × T sequential tasks × R communication rounds
(R/T rounds per task, 5 local epochs per round — paper trains 60 rounds over
6 tasks). Each round: extract prototypes → local train → upload → server
integration → dispatch → periodic retrieval evaluation (mAP/CMC, Eq. 7) and
forgetting (Eq. 8), plus exact S2C/C2S byte accounting.

Evaluation (``eval_backend="device"``, the default) is itself batched: all
(client, task) query sets live as padded/masked (C, T, Q, D) device arrays,
gallery prototypes are assembled once per (c, t) from the pre-extracted
query prototypes (the extraction layers are frozen, so they never change)
and padded to a common G, and one jitted program per eval round runs every
client's feature head (vmapped over the stacked eval pytree), all distance
matrices (kernels/pairwise_dist), and mAP/CMC + the per-(c, t) forgetting
bookkeeping inputs on device. ``eval_backend="host"`` retains the original
per-(client, task) numpy loop as the allclose oracle (and the fallback for
ragged benchmarks that cannot be stacked).

Two interchangeable engines drive the rounds:

  * ``engine="host"`` (default) — the original per-client Python loop: one
    jit dispatch per client per epoch, per-client state dicts, the server
    round over host lists of pytrees. Works for every strategy and is the
    allclose oracle for the stacked engine.
  * ``engine="stacked"`` — device-resident rounds for strategies that set
    ``supports_stacked`` (FedSTIL, STL): all C client states live as one
    stacked (C, ...) pytree, per-client minibatches are pre-gathered into
    (C, epochs, B, D) arrays (same rng draw order as the host engine, so
    both engines train on identical batches), local training for all C
    clients is a single vmap-over-clients of a scan-over-epochs, and the
    FedSTIL server round runs as one fused device program over a resident
    (C, k, D) relevance ring buffer. Metrics match the host engine to
    float tolerance; per-round wall time scales to C ≫ 100
    (``benchmarks/run.py --bench server`` tracks the ratio).
  * ``engine="sharded"`` — the stacked round, client-sharded over a
    ``Mesh(("data", "model"))`` of every host device: state, batches, the
    relevance ring and all eval inputs are placed row-sharded over "data"
    (``sharding/specs.py`` is the layout source of truth; C is padded to a
    multiple of the device count, padding rows masked out of the relevance
    ring) and the same jitted programs re-specialize into SPMD. Wire-bound
    buffers cross shards in bf16 (``common/precision.py``); optimizer/BN
    state stays f32. Metrics and measured comm bytes match the stacked
    engine (``benchmarks/run.py --bench mesh`` scales C → 10k).

Strategies that need raw images (iCaRL) or non-batchable local steps
(EWC/MAS consolidation, FedWeIT sparse uploads) simply keep the default
host engine.

Wire codecs (``Strategy(codec="topk+int8")``, see repro/comm/codec.py)
change what moves on the client<->server path in BOTH engines: every
upload/dispatch is encoded to real wire buffers, the comm log records the
MEASURED buffer bytes next to the analytic formulas
(``SimulationResult.comm_breakdown()``), and the receiver operates on the
decoded — possibly lossy — payload, so compression fidelity shows up in
the metrics. The stacked engine encodes all C clients' payload rows in one
jitted device program (kernels/topk_pack + kernels/quantize).
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.accounting import CommLog
from repro.core import edge_model as EM
from repro.data.synthetic import FederatedReIDBenchmark
from repro.evalreid import evaluate_retrieval
from repro.federated.base import Strategy
from repro.obs import trace as obs
from repro.train.metrics import LifelongTracker

EVAL_RANKS = (1, 3, 5)


@dataclasses.dataclass
class SimulationResult:
    name: str
    tracker: LifelongTracker
    comm: CommLog
    storage_bytes: int
    rounds: List[Dict[str, float]]      # per-eval-round mean metrics
    server_time_s: float = 0.0          # wall time inside server_round

    def final(self, key="mAP") -> float:
        return self.rounds[-1][key] if self.rounds else 0.0

    def final_metrics(self) -> Dict[str, float]:
        return self.rounds[-1] if self.rounds else {}

    def comm_breakdown(self) -> List[Dict[str, int]]:
        """Per-round measured-vs-formula wire bytes (both directions).
        With codecs active the *_wire columns are measured encoded-buffer
        sizes; without, they equal the analytic *_formula columns."""
        return self.comm.round_breakdown()


def _pre_extract_prototypes(bench: FederatedReIDBenchmark, g_params):
    """Extraction layers are frozen, so every task's train/query prototypes
    are computed up front — as ONE vmapped ``extract_prototypes`` call over
    the stacked (C·T, N, img_dim) array when task shapes are uniform (the
    benchmark default), falling back to per-task calls on ragged shapes."""
    C, T = bench.n_clients, bench.n_tasks
    tasks = [bench.task(c, t) for c in range(C) for t in range(T)]
    shapes = {(task.train_x.shape, task.query_x.shape) for task in tasks}
    protos = {}
    if len(shapes) == 1:
        n_train = tasks[0].train_x.shape[0]
        stacked = np.stack([np.concatenate([task.train_x, task.query_x])
                            for task in tasks])
        out = np.asarray(jax.vmap(
            lambda x: EM.extract_prototypes(g_params, x))(stacked))
        for i, task in enumerate(tasks):
            protos[(task.client, task.round)] = (
                out[i, :n_train], task.train_y,
                out[i, n_train:], task.query_y)
    else:
        for task in tasks:
            protos[(task.client, task.round)] = (
                np.asarray(EM.extract_prototypes(g_params, task.train_x)),
                task.train_y,
                np.asarray(EM.extract_prototypes(g_params, task.query_x)),
                task.query_y,
            )
    return protos


class _EvalCache:
    """Eval-round inputs, built once per simulation and reused every round.

    Galleries never change (the extraction layers are frozen and the
    gallery is the other clients' fixed query splits), so their prototypes
    are assembled per (c, t) from the pre-extracted query prototypes —
    never re-extracted per eval round. On top of that, when task shapes
    are uniform (the benchmark default) the query sets are stacked into
    device-resident padded (C, T, Q, D) arrays and the per-t galleries
    into (C, G_max, D) + validity masks (padded to the t = T-1 gallery
    size, so the jitted device eval program compiles exactly once per
    simulation). Galleries for past tasks are evicted as t advances —
    the task stream is monotone, they are never needed again.
    """

    def __init__(self, bench: FederatedReIDBenchmark, protos,
                 device: bool = True):
        self.bench = bench
        self.protos = protos
        C, T = bench.n_clients, bench.n_tasks
        qshapes = {protos[(c, t)][2].shape for c in range(C) for t in range(T)}
        self.uniform = len(qshapes) == 1
        # device stacks are only built when the device path will run them
        # (uniform shapes AND the caller asked for device eval)
        self.device_ready = device and self.uniform
        self._host_gal: Dict[Tuple[int, int], Tuple] = {}
        self._dev_t: Optional[int] = None
        self._dev_gal = None
        self._mesh = None
        self._padded: Optional[int] = None
        if self.device_ready:
            self.qp = jnp.asarray(np.stack(
                [np.stack([protos[(c, t)][2] for t in range(T)])
                 for c in range(C)]).astype(np.float32))        # (C, T, Q, D)
            self.qids = jnp.asarray(np.stack(
                [np.stack([protos[(c, t)][3] for t in range(T)])
                 for c in range(C)]).astype(np.int32))          # (C, T, Q)
            self.g_max = sum(protos[k][2].shape[0]
                             for k in bench.gallery_members(0, T - 1))
            # static per-query match bound for the counting-based ranking,
            # computed once against the LARGEST (t = T-1) galleries — valid
            # for every earlier t (galleries only shrink)
            from repro.evalreid.batched import max_match_bound
            self.max_matches = max(
                max_match_bound(
                    np.asarray(self.qids[c])[None],
                    np.concatenate([protos[k][3] for k in
                                    bench.gallery_members(c, T - 1)])[None])
                for c in range(C))

    def place(self, mesh, padded: int):
        """engine="sharded": pad every stacked eval input's client dim to
        the mesh-padded Cp (edge-replicating the last client row — padding
        rows are computed but never read back) and pin it to the client-row
        sharding from ``sharding.specs``, so the one jitted eval program
        runs SPMD with each device scoring its own client block."""
        if not self.device_ready:
            return
        self._mesh, self._padded = mesh, padded
        self.qp = self._place_rows(self.qp)
        self.qids = self._place_rows(self.qids)
        self._dev_t = None      # rebuild galleries padded + placed

    def _place_rows(self, arr):
        if self._mesh is None:
            return arr
        from repro.sharding import specs as shard_specs
        pad = self._padded - arr.shape[0]
        if pad:
            arr = jnp.concatenate([arr] + [arr[-1:]] * pad)
        sh = jax.sharding.NamedSharding(
            self._mesh, shard_specs.client_row_spec(arr.ndim))
        return jax.device_put(arr, sh)

    def host_gallery(self, c: int, t: int):
        """(gallery prototypes, gallery ids) for client c at task t —
        computed once per (c, t) from the pre-extracted query prototypes."""
        key = (c, t)
        if key not in self._host_gal:
            if self._host_gal and next(iter(self._host_gal))[1] != t:
                self._host_gal.clear()       # t is monotone: evict old tasks
            members = self.bench.gallery_members(c, t)
            self._host_gal[key] = (
                np.concatenate([self.protos[k][2] for k in members]),
                np.concatenate([self.protos[k][3] for k in members]))
        return self._host_gal[key]

    def device_gallery(self, t: int):
        """Stacked (C, G_max, D) gallery prototypes + (C, G_max) ids and
        validity mask for task t (None when the device stacks were not
        built — ragged benchmark or host-only eval)."""
        if not self.device_ready:
            return None
        if self._dev_t != t:
            C = self.bench.n_clients
            D = self.qp.shape[-1]
            gp = np.zeros((C, self.g_max, D), np.float32)
            gids = np.full((C, self.g_max), -1, np.int32)
            gmask = np.zeros((C, self.g_max), np.float32)
            for c in range(C):
                p, y = self.host_gallery(c, t)
                gp[c, :len(p)] = p
                gids[c, :len(y)] = y
                gmask[c, :len(p)] = 1.0
            self._dev_t = t
            self._dev_gal = tuple(
                self._place_rows(jnp.asarray(a)) for a in (gp, gids, gmask))
        return self._dev_gal

    def task_mask(self, t: int):
        C, T = self.bench.n_clients, self.bench.n_tasks
        m = np.zeros((C, T), np.float32)
        m[:, :t + 1] = 1.0
        return self._place_rows(jnp.asarray(m))


def _round_summary(tracker, rnd):
    per_round = {"round": rnd}
    for key in ("mAP",) + tuple(f"R{k}" for k in EVAL_RANKS):
        per_round[key] = tracker.mean_accuracy(rnd, key)
    per_round["forgetting_mAP"] = tracker.mean_forgetting(rnd, "mAP")
    per_round["forgetting_R1"] = tracker.mean_forgetting(rnd, "R1")
    return per_round


def _eval_round(strategy, get_state, bench, cache, tracker, rnd, t):
    """Host eval block (Eq. 7/8), the allclose oracle: per-client retrieval
    over all trained tasks. ``get_state(c)`` yields a ClientState-like view
    for client c. Gallery prototypes come from the per-(c, t) cache."""
    for c in range(bench.n_clients):
        state = get_state(c)
        gal_p, gal_y = cache.host_gallery(c, t)
        gal_f = strategy.features(state, gal_p)
        for tt in range(t + 1):
            _, _, qx, qy = cache.protos[(c, tt)]
            qf = strategy.features(state, qx)
            m = evaluate_retrieval(qf, qy, gal_f, gal_y, ranks=EVAL_RANKS)
            tracker.record(c, tt, rnd, m)
    return _round_summary(tracker, rnd)


def _eval_round_device(strategy, theta_stacked, cache, tracker, rnd, t):
    """Device eval block: every (client, trained task) mAP/CMC in ONE jitted
    program — vmapped feature heads over the stacked eval pytree, all
    distance matrices through the kernels/pairwise_dist path, metric math
    on device. Only the tiny (C, T, metrics) result is read back to feed
    the lifelong tracker (the Eq. 8 forgetting bookkeeping)."""
    gp, gids, gmask = cache.device_gallery(t)
    out = strategy.eval_round_stacked(
        theta_stacked, cache.qp, cache.qids, cache.task_mask(t),
        gp, gids, gmask, ranks=EVAL_RANKS, max_matches=cache.max_matches)
    out = {k: np.asarray(v) for k, v in out.items()}
    for c in range(cache.bench.n_clients):
        for tt in range(t + 1):
            tracker.record(c, tt, rnd,
                           {k: float(out[k][c, tt]) for k in out})
    return _round_summary(tracker, rnd)


def run_simulation(strategy: Strategy, bench: FederatedReIDBenchmark,
                   *, rounds: int = 12, eval_every: int = 2,
                   seed: int = 0, verbose: bool = False,
                   engine: str = "host",
                   eval_backend: str = "device",
                   trace=None) -> SimulationResult:
    """Drive ``rounds`` federated rounds of ``strategy`` over ``bench``.

    ``trace`` turns on telemetry for this run: a path writes the JSONL
    there (summarize with ``python -m repro.obs.report``); an
    ``obs.Tracer`` records into it without closing (the caller owns the
    sink). ``None`` (default) keeps every obs hook on the null tracer —
    no timestamps, no device syncs, no readbacks.
    """
    if trace is None:
        return _run_simulation(strategy, bench, rounds=rounds,
                               eval_every=eval_every, seed=seed,
                               verbose=verbose, engine=engine,
                               eval_backend=eval_backend)
    owns = not isinstance(trace, obs.Tracer)
    tracer = obs.Tracer(trace) if owns else trace
    tracer.meta(kind_detail="run_simulation", engine=engine, rounds=rounds,
                n_clients=bench.n_clients, strategy=strategy.name)
    try:
        with obs.active(tracer):
            return _run_simulation(strategy, bench, rounds=rounds,
                                   eval_every=eval_every, seed=seed,
                                   verbose=verbose, engine=engine,
                                   eval_backend=eval_backend)
    finally:
        if owns:
            tracer.close()


def _run_simulation(strategy: Strategy, bench: FederatedReIDBenchmark,
                    *, rounds: int, eval_every: int, seed: int,
                    verbose: bool, engine: str,
                    eval_backend: str) -> SimulationResult:
    if engine not in ("host", "stacked", "sharded"):
        raise ValueError(f"unknown engine {engine!r}")
    if eval_backend not in ("device", "host"):
        raise ValueError(f"unknown eval_backend {eval_backend!r}")
    if engine in ("stacked", "sharded") and not strategy.supports_stacked:
        raise ValueError(
            f"strategy {strategy.name!r} does not implement the stacked "
            f"engine API; use engine='host'")

    C, T = bench.n_clients, bench.n_tasks
    rounds_per_task = max(1, rounds // T)
    key = jax.random.PRNGKey(seed)

    # shared pre-trained extraction layers (paper: global pretrained weights)
    g_key, *client_keys = jax.random.split(key, C + 1)
    g_params = EM.init_extraction(g_key, strategy.cfg)

    states = {c: strategy.init_client(client_keys[c]) for c in range(C)}
    tracker = LifelongTracker(C)
    comm = CommLog()
    eval_rounds: List[Dict[str, float]] = []
    server_s = 0.0

    protos = _pre_extract_prototypes(bench, g_params)
    cache = _EvalCache(bench, protos, device=eval_backend == "device")
    # ragged benchmarks cannot be stacked — fall back to the host oracle
    eval_dev = cache.device_ready

    if engine in ("stacked", "sharded"):
        stacked = strategy.stack_states(states)
        valid_mask = None
        lead = C      # leading client dim of stacked payloads (Cp on a mesh)
        if engine == "sharded":
            # "computation follows data": build the engine mesh, pad + place
            # the stacked state / eval inputs row-sharded over "data", and
            # every existing jitted round program re-specializes into SPMD.
            # Padding clients train on replicated data; their validity-mask
            # zero keeps them out of the relevance ring (W rows/cols zero,
            # nz False), and byte accounting / eval read back only [:C].
            from repro.sharding import specs as shard_specs
            mesh = shard_specs.engine_mesh()
            stacked, valid_mask = strategy.shard_stacked_state(stacked, mesh)
            lead = strategy.padded_clients
            cache.place(mesh, lead)
        for rnd in range(rounds):
            t = min(rnd // rounds_per_task, T - 1)
            protos_list = [protos[(c, t)][0] for c in range(C)]
            labels_list = [protos[(c, t)][1] for c in range(C)]
            with obs.span("round.gather", cat="phase", round=rnd):
                bx, by = strategy.gather_round_batches(stacked, protos_list,
                                                       labels_list)
                bx, by = strategy.place_batches(bx, by)
            with obs.span("round.local_train", cat="phase", round=rnd) as sp:
                stacked, upload = strategy.local_train_stacked(
                    stacked, bx, by, protos_list, labels_list, rnd)
                sp.sync(stacked.trainable)
            if upload is not None:
                # per-client formula from the ACTUAL leading dim (Cp on a
                # mesh), logged for the C real clients — so measured and
                # formula bytes are engine-invariant at any device count
                formula = strategy.stacked_upload_bytes(upload, lead)
                if strategy.upload_codec is not None:
                    # one batched device encode/decode for all C rows; the
                    # server round consumes the decoded (lossy) upload
                    with obs.span("round.encode", cat="phase", round=rnd):
                        upload, measured = strategy.wire_upload_stacked(
                            upload)
                    comm.log_c2s_many(rnd, formula, C, measured=measured)
                else:
                    comm.log_c2s_many(rnd, formula, C)

            if strategy.uses_server and upload is not None:
                t0 = time.perf_counter()
                with obs.span("round.server", cat="phase", round=rnd) as sp:
                    dispatch = strategy.server_round_stacked(
                        rnd, upload, valid=valid_mask)
                    if dispatch is not None:
                        sp.sync(dispatch)   # dict shape is strategy-specific
                server_s += time.perf_counter() - t0
                if dispatch is not None:
                    per_client = strategy.stacked_dispatch_bytes(dispatch,
                                                                 lead)
                    nz = np.asarray(dispatch["nz"])[:C] if "nz" in dispatch \
                        else np.ones((C,), bool)
                    if strategy.dispatch_codec is not None:
                        # the stacked wire model is a BROADCAST stream: the
                        # codec encodes (and the delta refs advance for)
                        # ALL C rows every dispatch round, so all C are
                        # shipped and counted — every client can decode,
                        # including nz=False rows it won't apply. The host
                        # engine instead opens a per-client stream at that
                        # client's first non-empty dispatch; under partial
                        # nz its byte totals are lower by design.
                        with obs.span("round.encode", cat="phase",
                                      round=rnd):
                            dispatch, measured = \
                                strategy.wire_dispatch_stacked(dispatch)
                        # formula oracle keeps the host-engine semantics
                        # (one analytic dispatch per nz client)
                        comm.log_s2c_many(rnd, per_client, C,
                                          measured=measured,
                                          n_formula=int(nz.sum()))
                    else:
                        comm.log_s2c_many(rnd, per_client, int(nz.sum()))
                    with obs.span("round.apply", cat="phase",
                                  round=rnd) as sp:
                        stacked = strategy.apply_dispatch_stacked(stacked,
                                                                  dispatch)
                        sp.sync(stacked.extras)

            if (rnd + 1) % eval_every == 0 or rnd == rounds - 1:
                with obs.span("round.eval", cat="phase", round=rnd):
                    if eval_dev:
                        per_round = _eval_round_device(
                            strategy, strategy.eval_theta_stacked(stacked),
                            cache, tracker, rnd, t)
                    else:
                        per_round = _eval_round(
                            strategy,
                            lambda c: strategy.client_view(stacked, c),
                            bench, cache, tracker, rnd, t)
                eval_rounds.append(per_round)
                if verbose:
                    print(f"  [{strategy.name}/stacked] round {rnd}: "
                          f"mAP={per_round['mAP']:.4f} "
                          f"R1={per_round['R1']:.4f} "
                          f"F={per_round['forgetting_mAP']:.4f}")

        storage = max(strategy.storage_bytes(strategy.client_view(stacked, c))
                      for c in range(C))
        return SimulationResult(strategy.name, tracker, comm, storage,
                                eval_rounds, server_time_s=server_s)

    accepts_raw = "raw_images" in inspect.signature(strategy.local_train).parameters

    for rnd in range(rounds):
        t = min(rnd // rounds_per_task, T - 1)
        # EWC/MAS-style methods consolidate importance at task boundaries
        consolidate = ((rnd + 1) % rounds_per_task == 0) or rnd == rounds - 1
        uploads = {}
        with obs.span("round.local_train", cat="phase", round=rnd):
            for c in range(C):
                px, py, _, _ = protos[(c, t)]
                if accepts_raw:
                    task = bench.task(c, t)
                    states[c], up = strategy.local_train(
                        c, states[c], px, py, rnd,
                        raw_images=task.train_x, g_params=g_params,
                        consolidate=consolidate)
                else:
                    states[c], up = strategy.local_train(
                        c, states[c], px, py, rnd, consolidate=consolidate)
                if up is not None:
                    formula = strategy.upload_bytes(up)
                    if strategy.upload_codec is not None:
                        # the server integrates the DECODED (possibly
                        # lossy) upload — exactly what crossed the wire
                        up, measured = strategy.wire_upload(up, c)
                        comm.log_c2s(rnd, formula, measured=measured)
                    else:
                        comm.log_c2s(rnd, formula)
                    uploads[c] = up

        if strategy.uses_server and uploads:
            t0 = time.perf_counter()
            with obs.span("round.server", cat="phase", round=rnd):
                dispatches = strategy.server_round(rnd, uploads)
            server_s += time.perf_counter() - t0
            with obs.span("round.apply", cat="phase", round=rnd):
                for c, d in dispatches.items():
                    if d:
                        formula = strategy.dispatch_bytes(d)
                        if strategy.dispatch_codec is not None:
                            d, measured = strategy.wire_dispatch(d, c)
                            comm.log_s2c(rnd, formula, measured=measured)
                        else:
                            comm.log_s2c(rnd, formula)
                        states[c] = strategy.apply_dispatch(states[c], d)

        if (rnd + 1) % eval_every == 0 or rnd == rounds - 1:
            with obs.span("round.eval", cat="phase", round=rnd):
                if eval_dev:
                    per_round = _eval_round_device(
                        strategy, strategy.stack_eval_thetas(states), cache,
                        tracker, rnd, t)
                else:
                    per_round = _eval_round(strategy, lambda c: states[c],
                                            bench, cache, tracker, rnd, t)
            eval_rounds.append(per_round)
            if verbose:
                print(f"  [{strategy.name}] round {rnd}: "
                      f"mAP={per_round['mAP']:.4f} R1={per_round['R1']:.4f} "
                      f"F={per_round['forgetting_mAP']:.4f}")

    storage = max(strategy.storage_bytes(states[c]) for c in range(C))
    return SimulationResult(strategy.name, tracker, comm, storage, eval_rounds,
                            server_time_s=server_s)
