"""Federated lifelong simulation driver (paper §V experimental protocol).

C edge clients × T sequential tasks × R communication rounds
(R/T rounds per task, 5 local epochs per round — paper trains 60 rounds over
6 tasks). Each round: extract prototypes → local train → upload → server
integration → dispatch → periodic retrieval evaluation (mAP/CMC, Eq. 7) and
forgetting (Eq. 8), plus exact S2C/C2S byte accounting.

Two interchangeable engines drive the rounds:

  * ``engine="host"`` (default) — the original per-client Python loop: one
    jit dispatch per client per epoch, per-client state dicts, the server
    round over host lists of pytrees. Works for every strategy and is the
    allclose oracle for the stacked engine.
  * ``engine="stacked"`` — device-resident rounds for strategies that set
    ``supports_stacked`` (FedSTIL, STL): all C client states live as one
    stacked (C, ...) pytree, per-client minibatches are pre-gathered into
    (C, epochs, B, D) arrays (same rng draw order as the host engine, so
    both engines train on identical batches), local training for all C
    clients is a single vmap-over-clients of a scan-over-epochs, and the
    FedSTIL server round runs as one fused device program over a resident
    (C, k, D) relevance ring buffer. Metrics match the host engine to
    float tolerance; per-round wall time scales to C ≫ 100
    (``benchmarks/run.py --bench server`` tracks the ratio).

Strategies that need raw images (iCaRL) or non-batchable local steps
(EWC/MAS consolidation, FedWeIT sparse uploads) simply keep the default
host engine.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.comm.accounting import CommLog
from repro.core import edge_model as EM
from repro.data.synthetic import FederatedReIDBenchmark
from repro.evalreid import evaluate_retrieval
from repro.federated.base import Strategy
from repro.train.metrics import LifelongTracker


@dataclasses.dataclass
class SimulationResult:
    name: str
    tracker: LifelongTracker
    comm: CommLog
    storage_bytes: int
    rounds: List[Dict[str, float]]      # per-eval-round mean metrics
    server_time_s: float = 0.0          # wall time inside server_round

    def final(self, key="mAP") -> float:
        return self.rounds[-1][key] if self.rounds else 0.0

    def final_metrics(self) -> Dict[str, float]:
        return self.rounds[-1] if self.rounds else {}


def _pre_extract_prototypes(bench: FederatedReIDBenchmark, g_params):
    """Extraction layers are frozen, so every task's train/query prototypes
    are computed up front — as ONE vmapped ``extract_prototypes`` call over
    the stacked (C·T, N, img_dim) array when task shapes are uniform (the
    benchmark default), falling back to per-task calls on ragged shapes."""
    C, T = bench.n_clients, bench.n_tasks
    tasks = [bench.task(c, t) for c in range(C) for t in range(T)]
    shapes = {(task.train_x.shape, task.query_x.shape) for task in tasks}
    protos = {}
    if len(shapes) == 1:
        n_train = tasks[0].train_x.shape[0]
        stacked = np.stack([np.concatenate([task.train_x, task.query_x])
                            for task in tasks])
        out = np.asarray(jax.vmap(
            lambda x: EM.extract_prototypes(g_params, x))(stacked))
        for i, task in enumerate(tasks):
            protos[(task.client, task.round)] = (
                out[i, :n_train], task.train_y,
                out[i, n_train:], task.query_y)
    else:
        for task in tasks:
            protos[(task.client, task.round)] = (
                np.asarray(EM.extract_prototypes(g_params, task.train_x)),
                task.train_y,
                np.asarray(EM.extract_prototypes(g_params, task.query_x)),
                task.query_y,
            )
    return protos


def _eval_round(strategy, get_state, bench, g_params, protos, tracker,
                rnd, t):
    """Shared eval block (Eq. 7/8): per-client retrieval over all trained
    tasks. ``get_state(c)`` yields a ClientState-like view for client c."""
    per_round = {"round": rnd}
    for c in range(bench.n_clients):
        state = get_state(c)
        gal_x, gal_y = bench.gallery(c, t)
        gal_p = np.asarray(EM.extract_prototypes(g_params, gal_x))
        gal_f = strategy.features(state, gal_p)
        for tt in range(t + 1):
            _, _, qx, qy = protos[(c, tt)]
            qf = strategy.features(state, qx)
            m = evaluate_retrieval(qf, qy, gal_f, gal_y)
            tracker.record(c, tt, rnd, m)
    per_round["mAP"] = tracker.mean_accuracy(rnd, "mAP")
    per_round["R1"] = tracker.mean_accuracy(rnd, "R1")
    per_round["R3"] = tracker.mean_accuracy(rnd, "R3")
    per_round["R5"] = tracker.mean_accuracy(rnd, "R5")
    per_round["forgetting_mAP"] = tracker.mean_forgetting(rnd, "mAP")
    per_round["forgetting_R1"] = tracker.mean_forgetting(rnd, "R1")
    return per_round


def run_simulation(strategy: Strategy, bench: FederatedReIDBenchmark,
                   *, rounds: int = 12, eval_every: int = 2,
                   seed: int = 0, verbose: bool = False,
                   engine: str = "host") -> SimulationResult:
    if engine not in ("host", "stacked"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "stacked" and not strategy.supports_stacked:
        raise ValueError(
            f"strategy {strategy.name!r} does not implement the stacked "
            f"engine API; use engine='host'")

    C, T = bench.n_clients, bench.n_tasks
    rounds_per_task = max(1, rounds // T)
    key = jax.random.PRNGKey(seed)

    # shared pre-trained extraction layers (paper: global pretrained weights)
    g_key, *client_keys = jax.random.split(key, C + 1)
    g_params = EM.init_extraction(g_key, strategy.cfg)

    states = {c: strategy.init_client(client_keys[c]) for c in range(C)}
    tracker = LifelongTracker(C)
    comm = CommLog()
    eval_rounds: List[Dict[str, float]] = []
    server_s = 0.0

    protos = _pre_extract_prototypes(bench, g_params)

    if engine == "stacked":
        stacked = strategy.stack_states(states)
        for rnd in range(rounds):
            t = min(rnd // rounds_per_task, T - 1)
            protos_list = [protos[(c, t)][0] for c in range(C)]
            labels_list = [protos[(c, t)][1] for c in range(C)]
            bx, by = strategy.gather_round_batches(stacked, protos_list,
                                                   labels_list)
            stacked, upload = strategy.local_train_stacked(
                stacked, bx, by, protos_list, labels_list, rnd)
            if upload is not None:
                per_client = strategy.stacked_upload_bytes(upload, C)
                for _ in range(C):
                    comm.log_c2s(rnd, per_client)

            if strategy.uses_server and upload is not None:
                t0 = time.perf_counter()
                dispatch = strategy.server_round_stacked(rnd, upload)
                server_s += time.perf_counter() - t0
                if dispatch is not None:
                    per_client = strategy.stacked_dispatch_bytes(dispatch, C)
                    nz = np.asarray(dispatch["nz"]) if "nz" in dispatch \
                        else np.ones((C,), bool)
                    for c in range(C):
                        if nz[c]:
                            comm.log_s2c(rnd, per_client)
                    stacked = strategy.apply_dispatch_stacked(stacked,
                                                              dispatch)

            if (rnd + 1) % eval_every == 0 or rnd == rounds - 1:
                per_round = _eval_round(
                    strategy, lambda c: strategy.client_view(stacked, c),
                    bench, g_params, protos, tracker, rnd, t)
                eval_rounds.append(per_round)
                if verbose:
                    print(f"  [{strategy.name}/stacked] round {rnd}: "
                          f"mAP={per_round['mAP']:.4f} "
                          f"R1={per_round['R1']:.4f} "
                          f"F={per_round['forgetting_mAP']:.4f}")

        storage = max(strategy.storage_bytes(strategy.client_view(stacked, c))
                      for c in range(C))
        return SimulationResult(strategy.name, tracker, comm, storage,
                                eval_rounds, server_time_s=server_s)

    accepts_raw = "raw_images" in inspect.signature(strategy.local_train).parameters

    for rnd in range(rounds):
        t = min(rnd // rounds_per_task, T - 1)
        # EWC/MAS-style methods consolidate importance at task boundaries
        consolidate = ((rnd + 1) % rounds_per_task == 0) or rnd == rounds - 1
        uploads = {}
        for c in range(C):
            px, py, _, _ = protos[(c, t)]
            if accepts_raw:
                task = bench.task(c, t)
                states[c], up = strategy.local_train(
                    c, states[c], px, py, rnd,
                    raw_images=task.train_x, g_params=g_params,
                    consolidate=consolidate)
            else:
                states[c], up = strategy.local_train(c, states[c], px, py, rnd,
                                                     consolidate=consolidate)
            if up is not None:
                uploads[c] = up
                comm.log_c2s(rnd, strategy.upload_bytes(up))

        if strategy.uses_server and uploads:
            t0 = time.perf_counter()
            dispatches = strategy.server_round(rnd, uploads)
            server_s += time.perf_counter() - t0
            for c, d in dispatches.items():
                if d:
                    comm.log_s2c(rnd, strategy.dispatch_bytes(d))
                    states[c] = strategy.apply_dispatch(states[c], d)

        if (rnd + 1) % eval_every == 0 or rnd == rounds - 1:
            per_round = _eval_round(strategy, lambda c: states[c], bench,
                                    g_params, protos, tracker, rnd, t)
            eval_rounds.append(per_round)
            if verbose:
                print(f"  [{strategy.name}] round {rnd}: "
                      f"mAP={per_round['mAP']:.4f} R1={per_round['R1']:.4f} "
                      f"F={per_round['forgetting_mAP']:.4f}")

    storage = max(strategy.storage_bytes(states[c]) for c in range(C))
    return SimulationResult(strategy.name, tracker, comm, storage, eval_rounds,
                            server_time_s=server_s)
