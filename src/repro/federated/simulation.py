"""Federated lifelong simulation driver (paper §V experimental protocol).

C edge clients × T sequential tasks × R communication rounds
(R/T rounds per task, 5 local epochs per round — paper trains 60 rounds over
6 tasks). Each round: extract prototypes → local train → upload → server
integration → dispatch → periodic retrieval evaluation (mAP/CMC, Eq. 7) and
forgetting (Eq. 8), plus exact S2C/C2S byte accounting.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.comm.accounting import CommLog
from repro.core import edge_model as EM
from repro.data.synthetic import FederatedReIDBenchmark
from repro.evalreid import evaluate_retrieval
from repro.federated.base import Strategy
from repro.train.metrics import LifelongTracker


@dataclasses.dataclass
class SimulationResult:
    name: str
    tracker: LifelongTracker
    comm: CommLog
    storage_bytes: int
    rounds: List[Dict[str, float]]      # per-eval-round mean metrics
    server_time_s: float = 0.0          # wall time inside server_round

    def final(self, key="mAP") -> float:
        return self.rounds[-1][key] if self.rounds else 0.0

    def final_metrics(self) -> Dict[str, float]:
        return self.rounds[-1] if self.rounds else {}


def run_simulation(strategy: Strategy, bench: FederatedReIDBenchmark,
                   *, rounds: int = 12, eval_every: int = 2,
                   seed: int = 0, verbose: bool = False) -> SimulationResult:
    C, T = bench.n_clients, bench.n_tasks
    rounds_per_task = max(1, rounds // T)
    key = jax.random.PRNGKey(seed)

    # shared pre-trained extraction layers (paper: global pretrained weights)
    g_key, *client_keys = jax.random.split(key, C + 1)
    g_params = EM.init_extraction(g_key, strategy.cfg)

    states = {c: strategy.init_client(client_keys[c]) for c in range(C)}
    tracker = LifelongTracker(C)
    comm = CommLog()
    eval_rounds: List[Dict[str, float]] = []
    server_s = 0.0

    # pre-extract prototypes for every task (extraction layers are frozen)
    protos = {}
    for c in range(C):
        for t in range(T):
            task = bench.task(c, t)
            protos[(c, t)] = (
                np.asarray(EM.extract_prototypes(g_params, task.train_x)),
                task.train_y,
                np.asarray(EM.extract_prototypes(g_params, task.query_x)),
                task.query_y,
            )

    accepts_raw = "raw_images" in inspect.signature(strategy.local_train).parameters

    for rnd in range(rounds):
        t = min(rnd // rounds_per_task, T - 1)
        # EWC/MAS-style methods consolidate importance at task boundaries
        consolidate = ((rnd + 1) % rounds_per_task == 0) or rnd == rounds - 1
        uploads = {}
        for c in range(C):
            px, py, _, _ = protos[(c, t)]
            if accepts_raw:
                task = bench.task(c, t)
                states[c], up = strategy.local_train(
                    c, states[c], px, py, rnd,
                    raw_images=task.train_x, g_params=g_params,
                    consolidate=consolidate)
            else:
                states[c], up = strategy.local_train(c, states[c], px, py, rnd,
                                                     consolidate=consolidate)
            if up is not None:
                uploads[c] = up
                comm.log_c2s(rnd, strategy.upload_bytes(up))

        if strategy.uses_server and uploads:
            t0 = time.perf_counter()
            dispatches = strategy.server_round(rnd, uploads)
            server_s += time.perf_counter() - t0
            for c, d in dispatches.items():
                if d:
                    comm.log_s2c(rnd, strategy.dispatch_bytes(d))
                    states[c] = strategy.apply_dispatch(states[c], d)

        if (rnd + 1) % eval_every == 0 or rnd == rounds - 1:
            per_round = {"round": rnd}
            accs = []
            for c in range(C):
                gal_x, gal_y = bench.gallery(c, t)
                gal_p = np.asarray(EM.extract_prototypes(g_params, gal_x))
                gal_f = strategy.features(states[c], gal_p)
                for tt in range(t + 1):
                    _, _, qx, qy = protos[(c, tt)]
                    qf = strategy.features(states[c], qx)
                    m = evaluate_retrieval(qf, qy, gal_f, gal_y)
                    tracker.record(c, tt, rnd, m)
                accs.append(tracker.accuracy(c, rnd))
            per_round["mAP"] = tracker.mean_accuracy(rnd, "mAP")
            per_round["R1"] = tracker.mean_accuracy(rnd, "R1")
            per_round["R3"] = tracker.mean_accuracy(rnd, "R3")
            per_round["R5"] = tracker.mean_accuracy(rnd, "R5")
            per_round["forgetting_mAP"] = tracker.mean_forgetting(rnd, "mAP")
            per_round["forgetting_R1"] = tracker.mean_forgetting(rnd, "R1")
            eval_rounds.append(per_round)
            if verbose:
                print(f"  [{strategy.name}] round {rnd}: "
                      f"mAP={per_round['mAP']:.4f} R1={per_round['R1']:.4f} "
                      f"F={per_round['forgetting_mAP']:.4f}")

    storage = max(strategy.storage_bytes(states[c]) for c in range(C))
    return SimulationResult(strategy.name, tracker, comm, storage, eval_rounds,
                            server_time_s=server_s)
