"""Shared machinery for federated/lifelong strategies.

A Strategy owns per-client state and defines three hooks:

    local_train(client, state, task_protos, labels, rnd)  -> state, upload
    server_round(rnd, uploads)                            -> dispatches
    apply_dispatch(state, dispatch)                       -> state

The simulation (repro/federated/simulation.py) drives C clients through the
task stream, moving exactly the payloads each strategy declares — the comm
log measures those payloads, reproducing the paper's S2C/C2S accounting.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import edge_model as EM
from repro.train.optimizer import adam, apply_updates, clip_by_global_norm


@dataclasses.dataclass
class ClientState:
    theta: Any                        # the *trainable* pytree (strategy-defined)
    opt_state: Any = None
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Strategy:
    """Base: plain local training (STL)."""

    name = "stl"
    uses_server = False

    def __init__(self, cfg: EM.EdgeModelConfig, *, lr=1e-3, weight_decay=1e-5,
                 epochs=5, batch=64, seed=0):
        self.cfg = cfg
        self.lr = lr
        self.epochs = epochs
        self.batch = batch
        self.opt = adam(lr=lr, weight_decay=weight_decay)
        self._jit_cache: Dict[str, Callable] = {}
        self.rng = np.random.default_rng(seed)

    # ---- default loss: CE on adaptive layers --------------------------------
    def make_theta(self, trainable, extras):
        """Map the trainable pytree to actual adaptive params (identity for
        most methods; FedSTIL: theta = B ⊙ alpha + A)."""
        return trainable

    def loss(self, trainable, protos, labels, extras):
        return EM.ce_loss(self.make_theta(trainable, extras), protos, labels)

    def regularizer(self, trainable, extras):
        return 0.0

    # ---- generic minibatch trainer ------------------------------------------
    def _train_fn(self):
        if "train" not in self._jit_cache:
            @jax.jit
            def step(trainable, opt_state, protos, labels, extras):
                def lf(th):
                    return (self.loss(th, protos, labels, extras)
                            + self.regularizer(th, extras))
                loss, grads = jax.value_and_grad(lf)(trainable)
                grads, _ = clip_by_global_norm(grads, 1.0)
                updates, opt_state = self.opt.update(grads, opt_state, trainable)
                return apply_updates(trainable, updates), opt_state, loss
            self._jit_cache["train"] = step
        return self._jit_cache["train"]

    def _run_epochs(self, state: ClientState, protos, labels,
                    rehearsal: Optional[Tuple] = None):
        step = self._train_fn()
        n = len(protos)
        opt_state = state.opt_state or self.opt.init(state.theta)
        theta = state.theta
        extras = self._loss_extras(state)
        last = 0.0
        for _ in range(self.epochs):
            idx = self.rng.choice(n, size=min(self.batch, n), replace=n < self.batch)
            px, py = protos[idx], labels[idx]
            if rehearsal is not None:
                rx, ry = rehearsal
                # fixed rehearsal batch (static shapes -> single jit)
                ridx = self.rng.choice(len(rx), size=self.batch // 2, replace=True)
                px = np.concatenate([px, rx[ridx]])
                py = np.concatenate([py, ry[ridx]])
            theta, opt_state, loss = step(theta, opt_state,
                                          jnp.asarray(px), jnp.asarray(py), extras)
            last = float(loss)
        state.theta = theta
        state.opt_state = opt_state
        return state, last

    def _loss_extras(self, state: ClientState):
        ex = {k: v for k, v in state.extras.items() if k.startswith("reg_")}
        return ex if ex else {"reg_dummy": jnp.zeros(())}

    # ---- strategy API --------------------------------------------------------
    def init_client(self, key) -> ClientState:
        return ClientState(theta=EM.init_adaptive_layers(key, self.cfg))

    def local_train(self, client: int, state: ClientState, protos, labels,
                    rnd: int, **_):
        state, loss = self._run_epochs(state, protos, labels)
        return state, None   # STL uploads nothing

    def server_round(self, rnd: int, uploads: Dict[int, Any]) -> Dict[int, Any]:
        return {}

    def apply_dispatch(self, state: ClientState, dispatch) -> ClientState:
        return state

    # comm payload sizing (FedWeIT overrides with sparse accounting)
    def upload_bytes(self, upload) -> int:
        from repro.common.pytree import tree_bytes
        return tree_bytes(upload)

    def dispatch_bytes(self, dispatch) -> int:
        from repro.common.pytree import tree_bytes
        return tree_bytes(dispatch)

    def features(self, state: ClientState, protos):
        feats, _ = EM.adaptive_forward(self._eval_theta(state), jnp.asarray(protos))
        return np.asarray(feats)

    def _eval_theta(self, state: ClientState):
        return state.theta

    def storage_bytes(self, state: ClientState) -> int:
        from repro.common.pytree import tree_bytes
        return tree_bytes(state.theta)
