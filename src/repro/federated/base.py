"""Shared machinery for federated/lifelong strategies.

A Strategy owns per-client state and defines three hooks:

    local_train(client, state, task_protos, labels, rnd)  -> state, upload
    server_round(rnd, uploads)                            -> dispatches
    apply_dispatch(state, dispatch)                       -> state

The simulation (repro/federated/simulation.py) drives C clients through the
task stream, moving exactly the payloads each strategy declares — the comm
log measures those payloads, reproducing the paper's S2C/C2S accounting.

Strategies that set ``supports_stacked = True`` additionally implement the
*stacked* engine API: all C clients' trainable pytrees, optimizer states,
and loss extras live as ONE pytree whose leaves carry a leading (C, ...)
dim (``StackedClientState``), per-client minibatches are pre-gathered on
host into (C, epochs, B, D) arrays (drawing from ``self.rng`` in exactly
the per-client order the host path uses, so both engines see identical
batches), and local training for all C clients runs as a single
``jax.vmap``-over-clients of a ``lax.scan`` over epochs — one jit dispatch
per round instead of C×epochs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import register_program
from repro.comm.batched import BatchedCodec
from repro.comm.codec import make_codec
from repro.core import edge_model as EM
from repro.evalreid.batched import batched_retrieval_metrics
from repro.obs import trace as obs
from repro.sharding import specs as shard_specs
from repro.train.optimizer import adam, apply_updates, clip_by_global_norm


@dataclasses.dataclass
class ClientState:
    theta: Any                        # the *trainable* pytree (strategy-defined)
    opt_state: Any = None
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class StackedClientState:
    """All C clients' states as one device-resident stacked pytree.

    ``trainable`` / ``opt_state`` / ``extras`` leaves carry a leading C
    dim; ``host`` keeps per-client objects that cannot live on device
    (e.g. rehearsal memories) as plain length-C lists.
    """

    n_clients: int
    trainable: Any
    opt_state: Any
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)
    host: Dict[str, List[Any]] = dataclasses.field(default_factory=dict)


def _is_stackable(value) -> bool:
    """True when every leaf of ``value`` is an array (device-stackable)."""
    return all(isinstance(l, (jnp.ndarray, np.ndarray, jax.Array))
               or np.isscalar(l) for l in jax.tree.leaves(value))


def _stacked_eval_abstract():
    """Bench-scale abstract eval-round inputs (C=8 stacked clients)."""
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    cfg = EM.EdgeModelConfig()
    C, T, Q, G, D = 8, 3, 16, 96, cfg.proto_dim
    th = jax.eval_shape(lambda k: EM.init_adaptive_layers(k, cfg),
                        jax.random.PRNGKey(0))
    th = jax.tree.map(lambda l: S((C,) + l.shape, l.dtype), th)
    return ((th, S((C, T, Q, D), f32), S((C, T, Q), i32), S((C, T), f32),
             S((C, G, D), f32), S((C, G), i32), S((C, G), f32)),
            {"ranks": (1, 3, 5), "kernel_backend": "ref", "max_matches": 4})


@register_program(
    "federated.stacked_eval",
    abstract_args=_stacked_eval_abstract,
    oracle="repro.federated.simulation._eval_round", budget_bytes=64 << 20)
def stacked_eval_program(theta, qp, qids, task_mask, gp, gids, gmask, *,
                         ranks=(1, 3, 5), kernel_backend=None,
                         max_matches=None):
    """One traceable retrieval-eval round for all C clients x T tasks.

    theta: stacked eval-time adaptive pytree (leaves (C, ...));
    qp: (C, T, Q, D) query prototypes — ALL tasks' sets, including ones
    not yet trained (their rows hold real data; they are excluded via
    ``task_mask``, which sentinels their query ids to -2 so they can
    never match); qids: (C, T, Q); task_mask: (C, T) 1.0 = trained task;
    gp: (C, G, D) gallery prototypes padded to a common G; gids: (C, G);
    gmask: (C, G) gallery validity.

    The per-client feature heads are vmapped over the stacked pytree —
    gallery features use the masked BN variant (per-client gallery
    statistics over valid rows only), each (c, t) query set gets its own
    BN batch exactly like the per-client host path. Returns the
    ``batched_retrieval_metrics`` dict of (C, T) arrays.
    """
    gal_f = jax.vmap(
        lambda th, p, m: EM.adaptive_forward_masked(th, p, m)[0])(
            theta, gp, gmask)
    qf = jax.vmap(lambda th, sets: jax.vmap(
        lambda p: EM.adaptive_forward(th, p)[0])(sets))(theta, qp)
    qids_eff = jnp.where(task_mask[:, :, None] > 0,
                         qids.astype(jnp.int32), -2)
    return batched_retrieval_metrics(qf, qids_eff, gal_f, gids, gmask=gmask,
                                     ranks=ranks, backend=kernel_backend,
                                     max_matches=max_matches)


# The engine's ONE sharded eval program: the same ``stacked_eval_program``
# body the single-device engine jits, re-jitted with every leading-C input
# row-sharded over the mesh's "data" axis (layouts from sharding/specs) and
# the tiny (C, T) metric outputs replicated for the host readback. Cached
# per (mesh, config) — both ``Strategy.eval_round_stacked`` under
# ``engine="sharded"`` and the ``launch/eval_round`` CLI call this, so
# there is exactly one sharded eval implementation in the repo.
_SHARDED_EVAL_CACHE: Dict[Any, Callable] = {}


def sharded_eval_fn(mesh, *, ranks=(1, 3, 5), kernel_backend=None,
                    max_matches=None):
    key = (mesh, tuple(ranks), kernel_backend, max_matches)
    if key not in _SHARDED_EVAL_CACHE:
        rep = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, None))
        _SHARDED_EVAL_CACHE[key] = jax.jit(
            functools.partial(stacked_eval_program, ranks=tuple(ranks),
                              kernel_backend=kernel_backend,
                              max_matches=max_matches),
            out_shardings=rep)
    return _SHARDED_EVAL_CACHE[key]


def pad_client_rows(tree, n_to: int):
    """Pad every leaf's leading client dim to ``n_to`` by edge-replicating
    the last row. Replication (not zeros) keeps padded clients numerically
    boring: their forward/backward passes and eval rows compute real values
    (no 0/0 BN statistics), and the relevance mask guarantees they never
    influence a real client."""
    def pad(l):
        C = l.shape[0]
        if C == n_to:
            return l
        reps = jnp.broadcast_to(l[-1:], (n_to - C,) + l.shape[1:])
        return jnp.concatenate([jnp.asarray(l), reps], axis=0)
    return jax.tree.map(pad, tree)


class Strategy:
    """Base: plain local training (STL)."""

    name = "stl"
    uses_server = False
    # opt-in to the device-resident engine (run_simulation(engine="stacked")):
    # the generic machinery below handles any strategy whose loss/regularizer
    # depend only on array-valued ``reg_*`` extras; strategies with
    # non-batchable local steps (raw-image rehearsal, consolidation hooks,
    # sparse uploads) keep the host engine.
    supports_stacked = False

    def __init__(self, cfg: EM.EdgeModelConfig, *, lr=1e-3, weight_decay=1e-5,
                 epochs=5, batch=64, seed=0, codec=None, codec_opts=None):
        self.cfg = cfg
        self.lr = lr
        self.epochs = epochs
        self.batch = batch
        self.opt = adam(lr=lr, weight_decay=weight_decay)
        self._jit_cache: Dict[str, Callable] = {}
        self.rng = np.random.default_rng(seed)
        # wire codecs (repro.comm.codec): when set, the simulation encodes
        # every upload/dispatch, logs the MEASURED buffer bytes (formulas
        # stay as the cross-check oracle), and the receiver trains on the
        # decoded — possibly lossy — payload. One codec instance per
        # direction so delta state never crosses streams.
        self.codec_spec = codec
        self.codec_opts = dict(codec_opts or {})
        self.upload_codec = make_codec(codec, **self.codec_opts)
        self.dispatch_codec = make_codec(codec, **self.codec_opts)
        self._wire_programs: Dict[Any, BatchedCodec] = {}
        # engine="sharded": set by shard_stacked_state (None = stacked/host)
        self.mesh = None
        self.padded_clients: Optional[int] = None

    # ---- default loss: CE on adaptive layers --------------------------------
    def make_theta(self, trainable, extras):
        """Map the trainable pytree to actual adaptive params (identity for
        most methods; FedSTIL: theta = B ⊙ alpha + A)."""
        return trainable

    def loss(self, trainable, protos, labels, extras):
        return EM.ce_loss(self.make_theta(trainable, extras), protos, labels)

    def regularizer(self, trainable, extras):
        return 0.0

    # ---- generic minibatch trainer ------------------------------------------
    def _train_fn(self):
        if "train" not in self._jit_cache:
            @jax.jit
            def step(trainable, opt_state, protos, labels, extras):
                def lf(th):
                    return (self.loss(th, protos, labels, extras)
                            + self.regularizer(th, extras))
                loss, grads = jax.value_and_grad(lf)(trainable)
                grads, _ = clip_by_global_norm(grads, 1.0)
                updates, opt_state = self.opt.update(grads, opt_state, trainable)
                return apply_updates(trainable, updates), opt_state, loss
            self._jit_cache["train"] = step
        return self._jit_cache["train"]

    def _run_epochs(self, state: ClientState, protos, labels,
                    rehearsal: Optional[Tuple] = None):
        step = self._train_fn()
        n = len(protos)
        opt_state = state.opt_state or self.opt.init(state.theta)
        theta = state.theta
        extras = self._loss_extras(state)
        last = 0.0
        for _ in range(self.epochs):
            idx = self.rng.choice(n, size=min(self.batch, n), replace=n < self.batch)
            px, py = protos[idx], labels[idx]
            if rehearsal is not None:
                rx, ry = rehearsal
                # fixed rehearsal batch (static shapes -> single jit)
                ridx = self.rng.choice(len(rx), size=self.batch // 2, replace=True)
                px = np.concatenate([px, rx[ridx]])
                py = np.concatenate([py, ry[ridx]])
            theta, opt_state, loss = step(theta, opt_state,
                                          jnp.asarray(px), jnp.asarray(py), extras)
            last = float(loss)
        state.theta = theta
        state.opt_state = opt_state
        return state, last

    def _loss_extras(self, state: ClientState):
        ex = {k: v for k, v in state.extras.items() if k.startswith("reg_")}
        return ex if ex else {"reg_dummy": jnp.zeros(())}

    # ---- strategy API --------------------------------------------------------
    def init_client(self, key) -> ClientState:
        return ClientState(theta=EM.init_adaptive_layers(key, self.cfg))

    def local_train(self, client: int, state: ClientState, protos, labels,
                    rnd: int, **_):
        state, loss = self._run_epochs(state, protos, labels)
        return state, None   # STL uploads nothing

    def server_round(self, rnd: int, uploads: Dict[int, Any]) -> Dict[int, Any]:
        return {}

    def apply_dispatch(self, state: ClientState, dispatch) -> ClientState:
        return state

    # comm payload sizing (FedWeIT overrides with sparse accounting)
    def upload_bytes(self, upload) -> int:
        from repro.common.pytree import tree_bytes
        return tree_bytes(upload)

    def dispatch_bytes(self, dispatch) -> int:
        from repro.common.pytree import tree_bytes
        return tree_bytes(dispatch)

    # ---- wire codecs ---------------------------------------------------------
    # What part of a payload goes through the (lossy) codec vs ships
    # verbatim. Default: everything is codec traffic. FedSTIL overrides to
    # keep the tiny Eq. 3 task feature (the server's control plane) exact —
    # top-k sparsification across a concatenated payload would otherwise
    # let large theta entries starve it.

    def split_upload_for_wire(self, upload) -> Tuple[Any, Any]:
        """(codec subtree, verbatim subtree or None) for an upload."""
        return upload, None

    def join_upload_from_wire(self, decoded, verbatim):
        return decoded

    def split_dispatch_for_wire(self, dispatch) -> Tuple[Any, Any]:
        return dispatch, None

    def join_dispatch_from_wire(self, decoded, verbatim):
        return decoded

    def _wire_roundtrip(self, codec, tree, split, join, peer):
        """Encode -> measure -> decode one payload through a host codec
        (single-pass roundtrip: the reconstruction is computed once).
        Returns (the receiver-visible decoded payload, measured bytes
        including verbatim control tensors)."""
        from repro.common.pytree import tree_bytes
        lossy, verbatim = split(tree)
        with obs.span("comm.roundtrip", cat="codec", peer=list(peer)) as sp:
            decoded, payload = codec.roundtrip(lossy, peer=peer)
            sp.sync(decoded)
        measured = payload.nbytes
        if verbatim is not None:
            measured += tree_bytes(verbatim)
        return join(decoded, verbatim), measured

    def wire_upload(self, upload, client: int):
        """Host-engine C2S wire round-trip for one client's upload."""
        return self._wire_roundtrip(
            self.upload_codec, upload, self.split_upload_for_wire,
            self.join_upload_from_wire, ("c2s", client))

    def wire_dispatch(self, dispatch, client: int):
        """Host-engine S2C wire round-trip for one client's dispatch."""
        return self._wire_roundtrip(
            self.dispatch_codec, dispatch, self.split_dispatch_for_wire,
            self.join_dispatch_from_wire, ("s2c", client))

    def _stacked_wire_program(self, which: str, p: int) -> BatchedCodec:
        """Cached device codec program for one direction at payload size p
        (compiled once per simulation — p is fixed by the model)."""
        key = (which, p)
        if key not in self._wire_programs:
            template = (self.upload_codec if which == "upload"
                        else self.dispatch_codec)
            self._wire_programs[key] = BatchedCodec(template, p)
        return self._wire_programs[key]

    def _wire_roundtrip_stacked(self, which, tree, split, join):
        """Stacked-engine wire round-trip: ALL C clients' payload rows are
        encoded/decoded by one jitted device program (Pallas sparsify +
        quantize kernels via kernels.ops); measured per-client bytes come
        from the encoded buffer shapes — zero host readbacks."""
        from repro.common.pytree import (tree_bytes, tree_flatten_stacked,
                                         tree_unflatten_stacked)
        lossy, verbatim = split(tree)
        mat, meta = tree_flatten_stacked(lossy)
        C = mat.shape[0]
        prog = self._stacked_wire_program(which, int(mat.shape[1]))
        with obs.span(f"comm.{which}", cat="codec") as sp:
            recon, buffers = prog.roundtrip(mat)
            sp.sync(recon)
        # rider telemetry from the encode launch (residual norm = decoder-
        # reference staleness, kept energy, keep-rate); no-op readback
        # unless a tracer is active
        obs.metric("comm.encode", prog.last_metrics, direction=which)
        per_client = prog.per_client_bytes(buffers)
        if verbatim is not None:
            per_client += tree_bytes(verbatim) // max(C, 1)
        decoded = tree_unflatten_stacked(recon, meta)
        return join(decoded, verbatim), per_client

    def wire_upload_stacked(self, upload):
        return self._wire_roundtrip_stacked(
            "upload", upload, self.split_upload_for_wire,
            self.join_upload_from_wire)

    def wire_dispatch_stacked(self, dispatch):
        return self._wire_roundtrip_stacked(
            "dispatch", dispatch, self.split_dispatch_for_wire,
            self.join_dispatch_from_wire)

    def features(self, state: ClientState, protos):
        feats, _ = EM.adaptive_forward(self._eval_theta(state), jnp.asarray(protos))
        return np.asarray(feats)

    def _eval_theta(self, state: ClientState):
        return state.theta

    # ---- batched (device-resident) evaluation --------------------------------
    def stack_eval_thetas(self, states: Dict[int, "ClientState"]):
        """All C clients' eval-time adaptive params as one (C, ...) pytree
        (host-engine entry to the batched eval program)."""
        from repro.common.pytree import tree_stack
        return tree_stack([self._eval_theta(states[c])
                           for c in range(len(states))])

    def eval_theta_stacked(self, stacked: StackedClientState):
        """Stacked-engine counterpart of ``_eval_theta``: the (C, ...)
        eval-time params, straight off the resident state (no unstack)."""
        return stacked.trainable

    def eval_round_stacked(self, theta, qp, qids, task_mask, gp, gids, gmask,
                           *, ranks=(1, 3, 5), kernel_backend=None,
                           max_matches=None):
        """All C x T retrieval evaluations as one jitted device program
        (feature heads + Pallas distance kernel + mAP/CMC). Under
        ``engine="sharded"`` the same program runs client-row-sharded over
        the engine mesh via ``sharded_eval_fn``."""
        if self.mesh is not None:
            fn = sharded_eval_fn(self.mesh, ranks=tuple(ranks),
                                 kernel_backend=kernel_backend,
                                 max_matches=max_matches)
            return fn(theta, qp, qids, task_mask, gp, gids, gmask)
        key = f"eval:{tuple(ranks)}:{kernel_backend}:{max_matches}"
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(functools.partial(
                stacked_eval_program, ranks=tuple(ranks),
                kernel_backend=kernel_backend, max_matches=max_matches))
        return self._jit_cache[key](theta, qp, qids, task_mask, gp, gids,
                                    gmask)

    def storage_bytes(self, state: ClientState) -> int:
        from repro.common.pytree import tree_bytes
        return tree_bytes(state.theta)

    # ---- stacked (device-resident) engine API --------------------------------
    # One round = gather_round_batches (host rng, same draw order as the
    # host engine) -> local_train_stacked (single jit: vmap over clients of
    # a scan over epochs) -> server_round_stacked / apply_dispatch_stacked
    # (device-resident server program). ``client_view`` materialises one
    # client's slice for evaluation / storage accounting.

    def stack_states(self, states: Dict[int, "ClientState"]) -> StackedClientState:
        """Stack C per-client states into one (C, ...) pytree. Array-valued
        extras are stacked on device; everything else (rehearsal memories,
        host objects) moves to per-client ``host`` lists."""
        from repro.common.pytree import tree_stack
        C = len(states)
        ordered = [states[c] for c in range(C)]
        trainable = tree_stack([s.theta for s in ordered])
        opt_state = jax.vmap(self.opt.init)(trainable)
        extras: Dict[str, Any] = {}
        host: Dict[str, List[Any]] = {}
        for k in ordered[0].extras:
            vals = [s.extras[k] for s in ordered]
            if _is_stackable(vals[0]):
                extras[k] = tree_stack(vals)
            else:
                host[k] = vals
        return StackedClientState(n_clients=C, trainable=trainable,
                                  opt_state=opt_state, extras=extras,
                                  host=host)

    def client_view(self, stacked: StackedClientState, c: int) -> ClientState:
        """Client c's slice of the stacked state (for eval / storage)."""
        from repro.common.pytree import tree_slice
        ex = {k: tree_slice(v, c) for k, v in stacked.extras.items()}
        for k, vals in stacked.host.items():
            ex[k] = vals[c]
        return ClientState(theta=tree_slice(stacked.trainable, c),
                           opt_state=None, extras=ex)

    def _gather_rehearsal(self, stacked: StackedClientState, c: int):
        """Per-client rehearsal pool for this round (None = no rehearsal).
        Called once per client, first in the per-client rng draw order —
        exactly where the host path calls ``memory.sample``."""
        return None

    def gather_round_batches(self, stacked: StackedClientState,
                             protos_list, labels_list):
        """Pre-gather every client's epoch minibatches as dense arrays:
        (C, epochs, B, D) prototypes + (C, epochs, B) labels.

        Draws from ``self.rng`` in the host engine's exact order (client-
        major, then epoch; rehearsal pool first, then per-epoch batch and
        rehearsal indices) so both engines train on identical batches.
        """
        C = len(protos_list)
        bxs, bys = [], []
        for c in range(C):
            p, l = protos_list[c], labels_list[c]
            n = len(p)
            reh = self._gather_rehearsal(stacked, c)
            ex, ey = [], []
            for _ in range(self.epochs):
                idx = self.rng.choice(n, size=min(self.batch, n),
                                      replace=n < self.batch)
                px, py = p[idx], l[idx]
                if reh is not None:
                    rx, ry = reh
                    ridx = self.rng.choice(len(rx), size=self.batch // 2,
                                           replace=True)
                    px = np.concatenate([px, rx[ridx]])
                    py = np.concatenate([py, ry[ridx]])
                ex.append(px)
                ey.append(py)
            bxs.append(np.stack(ex))
            bys.append(np.stack(ey))
        shapes = {b.shape for b in bxs}
        if len(shapes) > 1:
            raise ValueError(
                f"stacked engine needs uniform per-client batch shapes, "
                f"got {sorted(shapes)} (ragged tasks/rehearsal pools)")
        return jnp.asarray(np.stack(bxs)), jnp.asarray(np.stack(bys))

    def _stacked_loss_extras(self, stacked: StackedClientState):
        ex = {k: v for k, v in stacked.extras.items() if k.startswith("reg_")}
        if ex:
            return ex
        # leading dim from the (possibly padded) trainable, not n_clients:
        # the vmapped train program needs every input row count to agree
        lead = jax.tree.leaves(stacked.trainable)[0].shape[0]
        return {"reg_dummy": jnp.zeros((lead,))}

    def _stacked_train_fn(self):
        """One jit: vmap over clients of a lax.scan over pre-gathered epoch
        batches — replaces C×epochs per-client jit dispatches per round."""
        if "stacked_train" not in self._jit_cache:
            # trainable/opt_state are round-carried: the caller overwrites
            # both with the returns, so the old buffers are donated (at
            # C >> 1000 an undonated stacked state doubles peak memory)
            @functools.partial(jax.jit, donate_argnums=(0, 1))
            def run(trainable, opt_state, extras, bx, by):
                def one_client(tr, os, ex, px, py):
                    def estep(carry, batch):
                        tr, os = carry
                        x, y = batch

                        def lf(th):
                            return (self.loss(th, x, y, ex)
                                    + self.regularizer(th, ex))
                        loss, grads = jax.value_and_grad(lf)(tr)
                        grads, _ = clip_by_global_norm(grads, 1.0)
                        updates, os = self.opt.update(grads, os, tr)
                        return (apply_updates(tr, updates), os), loss
                    (tr, os), losses = jax.lax.scan(estep, (tr, os), (px, py))
                    return tr, os, losses[-1]
                return jax.vmap(one_client)(trainable, opt_state, extras,
                                            bx, by)
            self._jit_cache["stacked_train"] = run
        return self._jit_cache["stacked_train"]

    def local_train_stacked(self, stacked: StackedClientState, bx, by,
                            protos_list, labels_list, rnd: int):
        """Train all C clients in one device program. Returns
        (stacked state, stacked upload pytree or None)."""
        run = self._stacked_train_fn()
        extras = self._stacked_loss_extras(stacked)
        trainable, opt_state, _ = run(stacked.trainable, stacked.opt_state,
                                      extras, bx, by)
        stacked.trainable = trainable
        stacked.opt_state = opt_state
        return stacked, None

    def server_round_stacked(self, rnd: int, upload, valid=None):
        """Device-resident server round over the stacked upload. ``valid``
        is the sharded engine's (Cp,) client-validity mask (1.0 for real
        clients, 0.0 for mesh-padding rows); None means every row is real
        (the single-device stacked engine)."""
        return None

    def apply_dispatch_stacked(self, stacked: StackedClientState, dispatch):
        return stacked

    # ---- sharded (mesh-resident) engine API ----------------------------------
    # engine="sharded" reuses the whole stacked round loop; the only deltas
    # are (1) the stacked state/batches are padded to Cp (a multiple of the
    # data-axis size) and placed with client-row NamedShardings so every
    # stacked jit runs SPMD over the mesh, and (2) the server round gets a
    # validity mask so padding rows never enter the relevance ring.

    def shard_stacked_state(self, stacked: StackedClientState, mesh):
        """Pad the stacked state to Cp rows and place it row-sharded on the
        engine mesh. Returns (stacked, valid) where valid is the (Cp,)
        client-validity mask. Host lists (rehearsal memories) stay length
        C — padding rows have no host-side identity."""
        C = stacked.n_clients
        Cp = shard_specs.padded_clients(C, mesh)
        self.mesh = mesh
        self.padded_clients = Cp

        def place(tree):
            padded = pad_client_rows(tree, Cp)
            sh = shard_specs.named_shardings(
                mesh, shard_specs.stacked_tree_specs(padded))
            return jax.device_put(padded, sh)

        stacked.trainable = place(stacked.trainable)
        stacked.opt_state = place(stacked.opt_state)
        stacked.extras = {k: place(v) for k, v in stacked.extras.items()}
        valid = jnp.concatenate([jnp.ones((C,), jnp.float32),
                                 jnp.zeros((Cp - C,), jnp.float32)])
        valid = jax.device_put(valid, jax.sharding.NamedSharding(
            mesh, shard_specs.client_row_spec(1)))
        return stacked, valid

    def place_batches(self, bx, by):
        """Pad this round's (C, epochs, B, ...) minibatch stacks to Cp rows
        and place them row-sharded (no-op outside the sharded engine)."""
        if self.mesh is None:
            return bx, by
        sh = shard_specs.named_shardings(
            self.mesh, shard_specs.stacked_tree_specs((bx, by)))
        return jax.device_put(
            (pad_client_rows(bx, self.padded_clients),
             pad_client_rows(by, self.padded_clients)), sh)

    def stacked_upload_bytes(self, upload, n_clients: int) -> int:
        """Per-client C2S bytes (stacked leaves carry C copies)."""
        from repro.common.pytree import tree_bytes
        return tree_bytes(upload) // max(n_clients, 1)

    def stacked_dispatch_bytes(self, dispatch, n_clients: int) -> int:
        from repro.common.pytree import tree_bytes
        return tree_bytes(dispatch) // max(n_clients, 1)
