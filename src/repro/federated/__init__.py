from repro.federated.base import ClientState, Strategy
from repro.federated.simulation import SimulationResult, run_simulation
from repro.federated.strategies import FedAvg, FedCurv, FedProx, FedWeIT
