"""Federated(-lifelong) baselines (paper Table II):

  * FedAvg  [Konečný+ 16]: upload theta, dispatch the uniform mean.
  * FedProx [Li+ 20]: FedAvg + proximal term μ/2||θ − θ_global||².
  * FedCurv [Shoham+ 19]: FedAvg + transmitted Fisher information — clients
    regularise towards *other* clients' important parameters. The extra
    matrices are exactly why its comm cost explodes in Table II.
  * FedWeIT [Yoon+ 21]: decomposed θ = B ⊙ m + A_local + Σ_j attn_j · A_j;
    sparse task-adaptive params are exchanged; needs task IDs (the paper
    grants it those). Settings (a)/(b) trade comm for accuracy via l1.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_bytes, tree_zeros_like
from repro.core import edge_model as EM
from repro.core.aggregation import fedavg_aggregate
from repro.federated.base import ClientState, Strategy


class FedAvg(Strategy):
    name = "fedavg"
    uses_server = True
    # the uniform mean is trivially batchable: the stacked/sharded engines
    # run it as one masked-mean device program over the (C|Cp, ...) rows
    supports_stacked = True

    def local_train(self, client, state, protos, labels, rnd, **_):
        state, _ = self._run_epochs(state, protos, labels)
        return state, {"theta": state.theta}

    def server_round(self, rnd, uploads):
        thetas = [u["theta"] for u in uploads.values()]
        mean = fedavg_aggregate(thetas)
        return {c: {"theta": mean} for c in uploads}

    def apply_dispatch(self, state, dispatch):
        state.theta = dispatch["theta"]
        state.opt_state = None          # fresh optimizer on new global params
        return state

    # ---- stacked / sharded engine -------------------------------------------
    def local_train_stacked(self, stacked, bx, by, protos_list, labels_list,
                            rnd):
        stacked, _ = super().local_train_stacked(stacked, bx, by,
                                                 protos_list, labels_list,
                                                 rnd)
        return stacked, {"theta": stacked.trainable}

    def server_round_stacked(self, rnd, upload, valid=None):
        """The FedAvg mean as one device program over the stacked rows.
        ``valid`` masks mesh-padding rows out of both the numerator and
        the denominator, so the mean is over the C real clients exactly as
        on the host; every row (padding included) receives the broadcast
        mean, matching the host's uniform dispatch."""
        theta = upload["theta"]
        lead = jax.tree.leaves(theta)[0].shape[0]
        if "stacked_mean" not in self._jit_cache:
            @jax.jit
            def mean_fn(th, mask):
                denom = jnp.maximum(jnp.sum(mask), 1.0)

                def m(l):
                    w = jnp.reshape(mask, (-1,) + (1,) * (l.ndim - 1))
                    mu = jnp.sum(l * w, axis=0) / denom
                    return jnp.broadcast_to(mu, l.shape)
                return jax.tree.map(m, th)
            self._jit_cache["stacked_mean"] = mean_fn
        mask = (jnp.ones((lead,), jnp.float32) if valid is None
                else jnp.asarray(valid, jnp.float32))
        return {"theta": self._jit_cache["stacked_mean"](theta, mask)}

    def apply_dispatch_stacked(self, stacked, dispatch):
        theta = dispatch["theta"]
        if self.mesh is not None:
            # keep the round-carried state client-row-sharded: the broadcast
            # mean comes back replicated, so re-pin the engine layout
            from repro.sharding import specs as shard_specs
            theta = jax.device_put(theta, shard_specs.named_shardings(
                self.mesh, shard_specs.stacked_tree_specs(theta)))
        stacked.trainable = theta
        # fresh optimizer on new global params (host: opt_state = None)
        stacked.opt_state = jax.vmap(self.opt.init)(stacked.trainable)
        return stacked


class FedProx(FedAvg):
    name = "fedprox"

    def __init__(self, cfg, *, mu=0.01, **kw):
        super().__init__(cfg, **kw)
        self.mu = mu

    def init_client(self, key):
        st = super().init_client(key)
        st.extras["reg_global"] = jax.tree.map(jnp.array, st.theta)
        return st

    def regularizer(self, trainable, extras):
        pen = sum(jnp.sum(jnp.square(t - g))
                  for t, g in zip(jax.tree.leaves(trainable),
                                  jax.tree.leaves(extras["reg_global"])))
        return 0.5 * self.mu * pen

    def apply_dispatch(self, state, dispatch):
        state = super().apply_dispatch(state, dispatch)
        state.extras["reg_global"] = dispatch["theta"]
        return state

    def apply_dispatch_stacked(self, stacked, dispatch):
        stacked = super().apply_dispatch_stacked(stacked, dispatch)
        # the proximal anchor follows the new global params (host parity).
        # A real copy, not an alias: the train program donates the
        # trainable buffers, and a donated buffer must not live on in the
        # (undonated) extras
        stacked.extras["reg_global"] = jax.tree.map(jnp.array,
                                                    stacked.trainable)
        return stacked


class FedCurv(FedAvg):
    name = "fedcurv"
    # Fisher estimation per upload is a host-side chunked vmap over raw
    # prototypes — not expressible as the engines' uniform batched step
    supports_stacked = False

    def __init__(self, cfg, *, lam=0.01, **kw):
        super().__init__(cfg, **kw)
        self.lam = lam

    def init_client(self, key):
        st = super().init_client(key)
        z = tree_zeros_like(st.theta)
        st.extras["reg_fisher_sum"] = z
        st.extras["reg_fisher_theta_sum"] = tree_zeros_like(st.theta)
        return st

    def regularizer(self, trainable, extras):
        # sum_j F_j (θ - θ_j)^2 = θ² ΣF - 2 θ Σ(Fθ) + const
        pen = sum(
            jnp.sum(fs * jnp.square(t)) - 2.0 * jnp.sum(ft * t)
            for fs, ft, t in zip(
                jax.tree.leaves(extras["reg_fisher_sum"]),
                jax.tree.leaves(extras["reg_fisher_theta_sum"]),
                jax.tree.leaves(trainable)))
        return 0.5 * self.lam * pen

    def _fisher(self, theta, protos, labels):
        # chunked (batch>=8): BN gradient is undefined at batch size 1
        n = (len(protos) // 8) * 8
        px = protos[:n].reshape(-1, 8, protos.shape[-1])
        py = labels[:n].reshape(-1, 8)
        g = jax.vmap(lambda x, y: jax.grad(EM.ce_loss)(theta, x, y))(px, py)
        return jax.tree.map(lambda gg: jnp.mean(jnp.square(gg), 0), g)

    def local_train(self, client, state, protos, labels, rnd, **_):
        state, _ = self._run_epochs(state, protos, labels)
        n = min(len(protos), 64)
        fisher = self._fisher(state.theta, jnp.asarray(protos[:n]),
                              jnp.asarray(labels[:n]))
        ftheta = jax.tree.map(lambda f, t: f * t, fisher, state.theta)
        # upload = theta + fisher + fisher*theta  (3x the FedAvg payload!)
        return state, {"theta": state.theta, "fisher": fisher, "ftheta": ftheta}

    def server_round(self, rnd, uploads):
        thetas = [u["theta"] for u in uploads.values()]
        mean = fedavg_aggregate(thetas)
        out = {}
        for c in uploads:
            others = [u for cc, u in uploads.items() if cc != c]
            fsum = jax.tree.map(lambda *xs: sum(xs), *[o["fisher"] for o in others])
            ftsum = jax.tree.map(lambda *xs: sum(xs), *[o["ftheta"] for o in others])
            out[c] = {"theta": mean, "fisher_sum": fsum, "ftheta_sum": ftsum}
        return out

    def apply_dispatch(self, state, dispatch):
        state.theta = dispatch["theta"]
        state.opt_state = None
        state.extras["reg_fisher_sum"] = dispatch["fisher_sum"]
        state.extras["reg_fisher_theta_sum"] = dispatch["ftheta_sum"]
        return state

    def storage_bytes(self, state):
        return (tree_bytes(state.theta)
                + tree_bytes(state.extras["reg_fisher_sum"])
                + tree_bytes(state.extras["reg_fisher_theta_sum"]))


class FedWeIT(Strategy):
    """θ_c = B ⊙ m_c + A_c + Σ_j α_cj A_j  with l1-sparse A.

    Exchanged: A_c up; base + all neighbours' (sparsified) A down.
    """

    name = "fedweit"
    uses_server = True

    def __init__(self, cfg, *, l1=1e-4, l2=1e-6, n_clients=5, **kw):
        super().__init__(cfg, **kw)
        self.l1 = l1
        self.l2 = l2
        self.n_clients = n_clients

    def init_client(self, key):
        base = EM.init_adaptive_layers(key, self.cfg)
        trainable = {
            "mask": jax.tree.map(jnp.ones_like, base),
            "A": jax.tree.map(jnp.zeros_like, base),
            "attn": jnp.zeros((self.n_clients,)),
        }
        st = ClientState(theta=trainable)
        st.extras["reg_base"] = base
        st.extras["reg_neighbors"] = jax.tree.map(
            lambda x: jnp.zeros((self.n_clients,) + x.shape, x.dtype), base)
        return st

    def make_theta(self, trainable, extras):
        base = extras["reg_base"]
        neigh = extras["reg_neighbors"]
        attn = jax.nn.softmax(trainable["attn"])
        theta = jax.tree.map(
            lambda b, m, a, nb: b * jax.nn.sigmoid(m) + a
            + jnp.einsum("c,c...->...", attn, nb),
            base, trainable["mask"], trainable["A"], neigh)
        return theta

    def regularizer(self, trainable, extras):
        l1 = sum(jnp.sum(jnp.abs(a)) for a in jax.tree.leaves(trainable["A"]))
        l2 = sum(jnp.sum(jnp.square(a)) for a in jax.tree.leaves(trainable["A"]))
        return self.l1 * l1 + self.l2 * l2

    def _sparsify(self, A, keep_frac=0.3):
        """Keep top-|keep_frac| magnitude entries (comm saving of l1)."""
        def sp(a):
            flat = jnp.abs(a).ravel()
            k = max(1, int(keep_frac * flat.size))
            thr = jnp.sort(flat)[-k]
            return jnp.where(jnp.abs(a) >= thr, a, 0.0)
        return jax.tree.map(sp, A)

    def sparse_bytes(self, A) -> int:
        """Effective sparse payload: fp32 values + int32 indices for the
        entries actually kept. Counts the real nonzeros of the sparsified
        tree — the old ``total * keep_frac`` closed form under-reported
        payload whenever ties at the top-k threshold made ``_sparsify``
        keep more than k entries (it keeps every ``|a| >= thr``). The codec
        tests assert this formula == the measured ``WirePayload`` bytes of
        a lossless sparse encoding."""
        kept = sum(int(np.count_nonzero(np.asarray(a)))
                   for a in jax.tree.leaves(A))
        return kept * (4 + 4)

    def local_train(self, client, state, protos, labels, rnd, **_):
        state, _ = self._run_epochs(state, protos, labels)
        A_sparse = self._sparsify(state.theta["A"])
        # nnz counted ONCE here (one device readback per upload) and
        # carried alongside the tree — the accounting hooks would
        # otherwise recount every neighbor copy per dispatch (O(C^2 * P)
        # host syncs per round at scale)
        return state, {"A": A_sparse, "base_grad": state.theta["mask"],
                       "A_nnz": self.sparse_bytes(A_sparse) // 8}

    def server_round(self, rnd, uploads):
        # base = fedavg of (B ⊙ sigmoid(mask)) proxies: here simply keep base
        # fixed and relay every client's sparse A to every other client.
        out = {}
        allA = {c: u["A"] for c, u in uploads.items()}
        nnz = {c: int(u["A_nnz"]) for c, u in uploads.items()}
        for c in uploads:
            out[c] = {"neighbors": allA, "neighbors_nnz": nnz}
        return out

    def apply_dispatch(self, state, dispatch):
        neigh = dispatch["neighbors"]
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[neigh[c] for c in sorted(neigh)])
        state.extras["reg_neighbors"] = stacked
        return state

    def _eval_theta(self, state):
        return self.make_theta(state.theta, state.extras)

    def storage_bytes(self, state):
        return (tree_bytes(state.theta) + tree_bytes(state.extras["reg_base"])
                + tree_bytes(state.extras["reg_neighbors"]))

    # accounting counters are control metadata, not wire payload: keep them
    # out of the lossy codec path (a large integer sharing a quantization
    # chunk with A entries would inflate that chunk's scale ~50x)
    def split_upload_for_wire(self, upload):
        return ({k: v for k, v in upload.items() if k != "A_nnz"},
                {"A_nnz": np.int64(upload["A_nnz"])})

    def join_upload_from_wire(self, decoded, verbatim):
        return {**decoded, **verbatim}

    def split_dispatch_for_wire(self, dispatch):
        return ({"neighbors": dispatch["neighbors"]},
                {"neighbors_nnz": {c: np.int64(n) for c, n in
                                   dispatch["neighbors_nnz"].items()}})

    def join_dispatch_from_wire(self, decoded, verbatim):
        return {**decoded, **verbatim}

    def upload_bytes(self, upload) -> int:
        nnz = upload.get("A_nnz")
        sparse = (int(nnz) * 8 if nnz is not None
                  else self.sparse_bytes(upload["A"]))
        return sparse + tree_bytes(upload["base_grad"])

    def dispatch_bytes(self, dispatch) -> int:
        nnz = dispatch.get("neighbors_nnz")
        if nnz is not None:
            return 8 * sum(int(n) for n in nnz.values())
        return sum(self.sparse_bytes(a) for a in dispatch["neighbors"].values())
