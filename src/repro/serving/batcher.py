"""Continuous batching front end for the retrieval engine.

Queries arrive one at a time, tagged with a client; the batcher coalesces
them into the engine's fixed-shape (C, B, proto_dim) device batches —
padding + validity masks, the same idiom as the stacked eval program — so
every launch amortizes dispatch over up to C*B queries. Because the
featurization uses frozen BN statistics, a query's answer is independent
of whichever batch it rides in: coalescing is purely a throughput choice,
never a semantics one (tested in tests/test_serving.py).

Admission policy: by default every client independently drains oldest
first at up to B slots/step ("fifo" — idle clients' slots go to padding,
so clients never contend). When a shared ``step_budget`` caps the total
slots per launch, "fifo" serves clients in index order and a hot client
can starve the rest; ``policy="drr"`` switches to deficit round robin —
each backlogged client earns ``quantum`` slots of credit per step, spends
credit when served, and the rotation start advances every step, so
sustained throughput per backlogged client converges to an equal share
while leftover slots still go to whoever has work (work conserving).

Latency accounting: a ``Ticket`` is stamped at submit and again when its
launch starts, so latency = queueing (``t_launch - t_submit``, waiting
for a slot) + service (``t_done - t_launch``, launch + readback) and the
two are separable per ticket.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import numpy as np

from repro.obs import trace as obs
from repro.obs.metrics import ServeStats


@dataclasses.dataclass
class Ticket:
    client: int
    qid: int
    t_submit: float
    t_launch: Optional[float] = None       # stamped when its launch starts
    t_done: Optional[float] = None
    ids: Optional[np.ndarray] = None       # (k,) top-k gallery ids
    dists: Optional[np.ndarray] = None     # (k,) squared distances

    @property
    def latency(self) -> float:
        if self.t_done is None:
            raise RuntimeError(
                f"ticket (client={self.client}, qid={self.qid}) is not "
                "completed yet — step()/drain() the batcher first")
        return self.t_done - self.t_submit

    @property
    def queue_s(self) -> float:
        """Time spent waiting for a batch slot."""
        if self.t_launch is None:
            raise RuntimeError(
                f"ticket (client={self.client}, qid={self.qid}) has not "
                "been launched yet — step()/drain() the batcher first")
        return self.t_launch - self.t_submit

    @property
    def service_s(self) -> float:
        """Launch + readback time of the batch it rode in."""
        if self.t_done is None:
            raise RuntimeError(
                f"ticket (client={self.client}, qid={self.qid}) is not "
                "completed yet — step()/drain() the batcher first")
        return self.t_done - self.t_launch


class ContinuousBatcher:
    """Coalesce a per-client query stream into fixed (C, B) batches.

    ``batch`` is the per-client slot budget B per launch; a launch fires
    whatever is queued (oldest first per client), padding the rest. A
    client with more than B pending queries drains over several steps.

    ``step_budget`` (optional) caps TOTAL slots per launch across
    clients; ``policy`` picks how a scarce budget is split ("fifo" =
    client-index order, "drr" = deficit round robin with ``quantum``
    slots of credit per backlogged client per step, default
    budget // n_clients).

    ``stats`` (an ``obs.ServeStats``) turns on runtime serving metrics:
    every step records queue depth before admission, completed-ticket
    latencies into the fixed-bucket histograms (exact p50/p99 from the
    buckets), completions into the rolling QPS meter, and — under drr —
    the per-client deficit snapshot. ``None`` (default) records nothing.
    """

    def __init__(self, engine, batch: int = 32, *, policy: str = "fifo",
                 step_budget: Optional[int] = None,
                 quantum: Optional[int] = None,
                 stats: Optional[ServeStats] = None):
        if policy not in ("fifo", "drr"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.engine = engine
        self.batch = batch
        self.policy = policy
        self.stats = stats
        C = engine.index.n_clients
        self.step_budget = (C * batch if step_budget is None
                            else min(step_budget, C * batch))
        self.quantum = (max(1, self.step_budget // C) if quantum is None
                        else quantum)
        Dp = engine.index.gp.shape[-1]
        self._queues = [deque() for _ in range(C)]
        self._deficit = np.zeros(C, np.int64)
        self._rr = 0                        # rotation start for drr
        self._qp = np.zeros((C, batch, Dp), np.float32)
        self._qmask = np.zeros((C, batch), np.float32)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues)

    def submit(self, client: int, proto: np.ndarray, qid: int = -1,
               now: Optional[float] = None) -> Ticket:
        t = Ticket(client=client, qid=qid,
                   t_submit=time.perf_counter() if now is None else now)
        self._queues[client].append((t, np.asarray(proto, np.float32)))
        return t

    def _admit(self) -> List[int]:
        """Slots granted per client this step, honoring policy + budget."""
        C = len(self._queues)
        want = [min(len(q), self.batch) for q in self._queues]
        grant = [0] * C
        left = self.step_budget
        order = [(self._rr + i) % C for i in range(C)]
        if self.policy == "drr":
            for c in range(C):
                # credit accrues only while backlogged; an idle client's
                # stale credit would otherwise burst-starve the others
                self._deficit[c] = (self._deficit[c] + self.quantum
                                    if want[c] else 0)
            for c in order:
                n = min(want[c], int(self._deficit[c]), left)
                grant[c] = n
                self._deficit[c] -= n
                left -= n
            self._rr = (self._rr + 1) % C
        else:
            order = range(C)
        # work conserving: leftover budget goes to remaining backlog in
        # order (fifo does all its granting here)
        for c in order:
            n = min(want[c] - grant[c], left)
            grant[c] += n
            left -= n
        return grant

    def step(self) -> List[Ticket]:
        """Run one coalesced launch over the admitted pending queries.
        Returns the tickets completed by this launch (empty when idle)."""
        depth = self.pending
        self._qp[:] = 0.0
        self._qmask[:] = 0.0
        grant = self._admit()
        taken: List[List[Ticket]] = []
        for c, q in enumerate(self._queues):
            row = []
            while q and len(row) < grant[c]:
                t, proto = q.popleft()
                self._qp[c, len(row)] = proto
                self._qmask[c, len(row)] = 1.0
                row.append(t)
            taken.append(row)
        if not any(taken):
            return []
        n_slots = sum(len(row) for row in taken)
        launch = time.perf_counter()
        with obs.span("serve.batch", cat="serve", slots=n_slots):
            # query_batch returns numpy: the readback IS the sync boundary
            ids, dists = self.engine.query_batch(self._qp, self._qmask)
        done = time.perf_counter()
        out = []
        for c, row in enumerate(taken):
            for b, t in enumerate(row):
                t.t_launch = launch
                t.t_done = done
                t.ids = ids[c, b]
                t.dists = dists[c, b]
                out.append(t)
        if self.stats is not None:
            self.stats.record_launch(
                depth, self._deficit if self.policy == "drr" else None)
            for t in out:
                self.stats.record_ticket(t)
        return out

    def drain(self) -> List[Ticket]:
        """Step until every pending query is answered."""
        out = []
        while self.pending:
            out.extend(self.step())
        return out


def _latency_stats(tickets) -> dict:
    lat = np.array([t.latency for t in tickets])
    que = np.array([t.queue_s for t in tickets])
    srv = np.array([t.service_s for t in tickets])
    return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "queue_p50_ms": float(np.percentile(que, 50) * 1e3),
            "queue_p99_ms": float(np.percentile(que, 99) * 1e3),
            "service_p50_ms": float(np.percentile(srv, 50) * 1e3),
            "service_p99_ms": float(np.percentile(srv, 99) * 1e3)}


def run_closed_loop(batcher: ContinuousBatcher, stream) -> dict:
    """Submit every (client, proto, qid) then drain: peak-throughput
    measurement (QPS) plus latency percentiles (queue/service split)."""
    t0 = time.perf_counter()
    for client, proto, qid in stream:
        batcher.submit(client, proto, qid)
    tickets = batcher.drain()
    wall = time.perf_counter() - t0
    return {"n": len(tickets), "wall_s": wall,
            "qps": len(tickets) / wall,
            **_latency_stats(tickets),
            "tickets": tickets}


def run_open_loop(batcher: ContinuousBatcher, stream, rate_qps: float) -> dict:
    """Paced arrivals at ``rate_qps`` (uniform spacing): the latency a
    client actually sees at that load — queueing + service.

    Tickets are stamped with their SCHEDULED arrival time, so reported
    latency includes any pacing slip (the pacer sleeps to the next
    deadline and submits every due arrival on wake — it never oversleeps
    one deadline per ticket the way a per-ticket re-poll would)."""
    stream = list(stream)
    gap = 1.0 / rate_qps
    tickets = []
    t0 = time.perf_counter()
    i = 0
    while len(tickets) < len(stream):
        now = time.perf_counter()
        while i < len(stream) and t0 + i * gap <= now:
            client, proto, qid = stream[i]
            batcher.submit(client, proto, qid, now=t0 + i * gap)
            i += 1
        if batcher.pending:
            tickets.extend(batcher.step())
        elif i < len(stream):
            time.sleep(max(0.0, t0 + i * gap - time.perf_counter()))
    wall = time.perf_counter() - t0
    return {"n": len(tickets), "wall_s": wall, "rate_qps": rate_qps,
            "qps": len(tickets) / wall,
            **_latency_stats(tickets),
            "tickets": tickets}
