"""Continuous batching front end for the retrieval engine.

Queries arrive one at a time, tagged with a client; the batcher coalesces
them into the engine's fixed-shape (C, B, proto_dim) device batches —
padding + validity masks, the same idiom as the stacked eval program — so
every launch amortizes dispatch over up to C*B queries. Because the
featurization uses frozen BN statistics, a query's answer is independent
of whichever batch it rides in: coalescing is purely a throughput choice,
never a semantics one (tested in tests/test_serving.py).

Latency accounting: a ``Ticket`` is stamped at submit; ``step()`` stamps
completion after results are back on host, so ticket latency = queueing
(waiting for a slot in a batch) + service (launch + readback).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Ticket:
    client: int
    qid: int
    t_submit: float
    t_done: Optional[float] = None
    ids: Optional[np.ndarray] = None       # (k,) top-k gallery ids
    dists: Optional[np.ndarray] = None     # (k,) squared distances

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


class ContinuousBatcher:
    """Coalesce a per-client query stream into fixed (C, B) batches.

    ``batch`` is the per-client slot budget B per launch; a launch fires
    whatever is queued (oldest first per client), padding the rest. A
    client with more than B pending queries drains over several steps.
    """

    def __init__(self, engine, batch: int = 32):
        self.engine = engine
        self.batch = batch
        C = engine.index.n_clients
        Dp = engine.index.gp.shape[-1]
        self._queues = [deque() for _ in range(C)]
        self._qp = np.zeros((C, batch, Dp), np.float32)
        self._qmask = np.zeros((C, batch), np.float32)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues)

    def submit(self, client: int, proto: np.ndarray, qid: int = -1,
               now: Optional[float] = None) -> Ticket:
        t = Ticket(client=client, qid=qid,
                   t_submit=time.perf_counter() if now is None else now)
        self._queues[client].append((t, np.asarray(proto, np.float32)))
        return t

    def step(self) -> List[Ticket]:
        """Run one coalesced launch over the oldest pending queries.
        Returns the tickets completed by this launch (empty when idle)."""
        self._qp[:] = 0.0
        self._qmask[:] = 0.0
        taken: List[List[Ticket]] = []
        for c, q in enumerate(self._queues):
            row = []
            while q and len(row) < self.batch:
                t, proto = q.popleft()
                self._qp[c, len(row)] = proto
                self._qmask[c, len(row)] = 1.0
                row.append(t)
            taken.append(row)
        if not any(taken):
            return []
        ids, dists = self.engine.query_batch(self._qp, self._qmask)
        done = time.perf_counter()
        out = []
        for c, row in enumerate(taken):
            for b, t in enumerate(row):
                t.t_done = done
                t.ids = ids[c, b]
                t.dists = dists[c, b]
                out.append(t)
        return out

    def drain(self) -> List[Ticket]:
        """Step until every pending query is answered."""
        out = []
        while self.pending:
            out.extend(self.step())
        return out


def run_closed_loop(batcher: ContinuousBatcher, stream) -> dict:
    """Submit every (client, proto, qid) then drain: peak-throughput
    measurement (QPS) plus service-latency percentiles."""
    t0 = time.perf_counter()
    for client, proto, qid in stream:
        batcher.submit(client, proto, qid)
    tickets = batcher.drain()
    wall = time.perf_counter() - t0
    lat = np.array([t.latency for t in tickets])
    return {"n": len(tickets), "wall_s": wall,
            "qps": len(tickets) / wall,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "tickets": tickets}


def run_open_loop(batcher: ContinuousBatcher, stream, rate_qps: float) -> dict:
    """Paced arrivals at ``rate_qps`` (uniform spacing): the latency a
    client actually sees at that load — queueing + service."""
    stream = list(stream)
    gap = 1.0 / rate_qps
    tickets = []
    t0 = time.perf_counter()
    i = 0
    while len(tickets) < len(stream):
        now = time.perf_counter()
        while i < len(stream) and t0 + i * gap <= now:
            client, proto, qid = stream[i]
            batcher.submit(client, proto, qid)
            i += 1
        if batcher.pending:
            tickets.extend(batcher.step())
        elif i < len(stream):
            time.sleep(max(0.0, t0 + i * gap - time.perf_counter()))
    wall = time.perf_counter() - t0
    lat = np.array([t.latency for t in tickets])
    return {"n": len(tickets), "wall_s": wall, "rate_qps": rate_qps,
            "qps": len(tickets) / wall,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "tickets": tickets}
