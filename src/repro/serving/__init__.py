"""Online ReID retrieval serving (the QPS half of the north star).

See README.md in this directory for the index layout, the batching
contract, and the update protocol.
"""
from repro.serving.batcher import (ContinuousBatcher, Ticket,
                                   run_closed_loop, run_open_loop)
from repro.serving.engine import (RetrievalEngine, map_from_ranked_ids,
                                  query_host, query_ivf_host, recall_at_k)
from repro.serving.index import (GalleryIndex, index_refresh_ivf_program,
                                 index_refresh_program)

__all__ = [
    "ContinuousBatcher", "Ticket", "run_closed_loop", "run_open_loop",
    "RetrievalEngine", "map_from_ranked_ids", "query_host",
    "query_ivf_host", "recall_at_k",
    "GalleryIndex", "index_refresh_program", "index_refresh_ivf_program",
]
