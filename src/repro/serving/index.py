"""Device-resident per-client gallery index for online ReID retrieval.

Layout (all leading-axis C = clients, fixed capacity G rows per client so
every refresh/query compiles once):

  host side (the cloud's copy, never re-extracted):
    gp         (C, G, proto_dim) fp32   gallery prototypes (Eq. 1 outputs)
    gids_host  (C, G) int32             person ids, -1 = empty slot
  device side (rebuilt by ONE jitted refresh when a federated round lands
  a new adaptive head — prototypes are reused, only the head math reruns):
    gq         (C, G, feat_dim) int8    quantized L2-normalized features
    gscale     (C, G) fp32              per-ROW symmetric scale (absmax/127)
    gn2        (C, G) fp32              |dequant(row)|^2 (kernel never
                                        re-reduces the gallery)
    gids       (C, G) int32             device copy of gids_host
    bn_mu/sd   (C, feat_dim) fp32       BN statistics frozen over each
                                        client's valid gallery rows — the
                                        query featurization uses THESE, so
                                        results are batch-composition
                                        independent (see engine/batcher)
    gf         (C, G, feat_dim) fp32    optional exact fp32 rows, kept only
                                        when the index doubles as the
                                        parity/fidelity oracle

Resident bytes per row: feat_dim + 8 (int8 codes + scale + norm) vs
4*feat_dim + 8 fp32 — ~3.7x more rows in the same device budget at
feat_dim=64 (the "4x capacity" the quantize kernel buys, less the two
fp32 sidecars).

IVF image (optional, ``nlist > 0``; built by the same refresh launch so
the coarse quantizer always matches the head that produced the rows):

    cent  (C, nlist, F) fp32        coarse centroids (k-means over the
                                    valid dequantized rows)
    cn2   (C, nlist) fp32           |centroid|^2
    bq    (C, nlist, bcap, F) int8  bucket-major copy of the row codes
                                    (empty slots zeroed)
    pack  (C, nlist, 3, bcap) fp32  [row scale; dequant |g|^2; person id
                                    bitcast int32->f32] — one contiguous
                                    sidecar load per probed bucket
    binv  (C, nlist, bcap) int32    gallery ROW index per slot (-1 empty;
                                    the build invariant: every valid row
                                    sits in exactly one slot)

Bucket shapes are static: nlist ~ sqrt(2G) centroids, bcap ~ 1.4 * G /
nlist slots (headroom over the mean occupancy; a mild count-balance
penalty in Lloyd keeps the tail under it, and overflow rows spill to
empty slots elsewhere so none are dropped — recall@k == 1.0 at
nprobe == nlist is structural, not statistical).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import register_program
from repro.core import edge_model as EM
from repro.kernels import ops

_EPS = 1e-12


def _l2n(x):
    return x / jnp.sqrt(jnp.maximum(jnp.sum(jnp.square(x), -1,
                                            keepdims=True), _EPS))


def _refresh_abstract():
    cfg = EM.EdgeModelConfig()
    theta = jax.eval_shape(
        lambda k: EM.init_adaptive_layers(k, cfg), jax.random.PRNGKey(0))
    C, G = 8, 4096
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((C,) + s.shape, s.dtype), theta)
    return ((stacked,
             jax.ShapeDtypeStruct((C, G, cfg.proto_dim), jnp.float32),
             jax.ShapeDtypeStruct((C, G), jnp.float32)),
            {"backend": "ref"})


@register_program(
    "serving.index_refresh",
    abstract_args=_refresh_abstract,
    oracle="repro.serving.index.refresh_host", budget_bytes=192 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def index_refresh_program(theta, gp, gmask, *, backend: str = None):
    """Rebuild the resident index under a (stacked) adaptive head:
    (C, G, proto_dim) prototypes + (C, G) validity -> int8 codes, per-row
    scales, dequantized squared norms, frozen BN stats, and the exact fp32
    rows (the caller drops those unless it keeps the parity oracle).

    Features are L2-normalized before quantization so every row shares the
    same dynamic range; empty slots are zeroed (scale 1, norm 0)."""
    f = jax.vmap(EM.adaptive_pre_bn)(theta, gp)
    mu, sd = jax.vmap(EM.adaptive_bn_stats)(f, gmask)
    fn = jax.vmap(EM.adaptive_bn_apply)(theta, f, mu, sd)
    fn = _l2n(fn) * gmask[..., None]
    C, G, F = fn.shape
    q8, scales = ops.batched_quantize(fn.reshape(C, G * F), chunk=F,
                                      backend=backend)
    gq = q8.reshape(C, G, F)
    gn2 = (jnp.sum(jnp.square(gq.astype(jnp.float32)), -1)
           * jnp.square(scales))
    return gq, scales, gn2, mu, sd, fn


def _ivf_build_one(deq, gmask, *, nlist: int, bcap: int, iters: int,
                   train_cap: int, balance: float):
    """Fixed-shape balanced k-means + capacity placement for ONE client.

    deq (G, F) dequantized rows, gmask (G,) validity -> (cent (nlist, F),
    cn2 (nlist,), inv (nlist, bcap) int32 row indices, -1 = empty slot).

    Everything is static-shape so the build vmaps over clients inside one
    jitted refresh: valid rows are argsort-compacted to a prefix, Lloyd
    runs over a strided subsample with a count-balance penalty
    ``balance * (est_count/target - 1)`` added to the assignment metric
    (query-time probing stays unpenalized), and placement is a stable
    sort by (bucket, row): the first bcap rows of a bucket take its
    slots, overflow rows spill — in row order — into the globally
    leftover empty slots, so every valid row lands in exactly one slot
    (nlist * bcap >= G is validated by the index)."""
    G, F = deq.shape
    valid = gmask > 0
    g_idx = jnp.arange(G, dtype=jnp.int32)
    vorder = jnp.argsort(jnp.where(valid, g_idx, G + g_idx))
    nv = jnp.maximum(jnp.sum(valid.astype(jnp.int32)), 1)
    S = min(G, train_cap)
    tpick = (jnp.arange(S, dtype=jnp.int32) * nv) // S
    train = deq[vorder[tpick]]
    tm = gmask[vorder[tpick]]               # all-invalid client -> zeros
    cpick = (jnp.arange(nlist, dtype=jnp.int32) * nv) // nlist
    cent = deq[vorder[cpick]]
    target = jnp.maximum(nv.astype(jnp.float32) / nlist, 1e-6)

    def assign_chunked(rows, cent, pen, chunk):
        n = rows.shape[0]
        pad = (-n) % chunk
        rp = jnp.pad(rows, ((0, pad), (0, 0)))
        cn2 = jnp.sum(cent * cent, -1)

        def one(cr):
            d = (jnp.sum(cr * cr, -1, keepdims=True) + cn2[None, :]
                 - 2.0 * cr @ cent.T)
            return jnp.argmin(d + pen[None, :], -1).astype(jnp.int32)

        return jax.lax.map(one, rp.reshape(-1, chunk, F)).reshape(-1)[:n]

    cnt_est = jnp.full((nlist,), 1.0) * target    # zero penalty at start
    for _ in range(iters):
        pen = balance * (cnt_est / target - 1.0)
        a = assign_chunked(train, cent, pen, 512)
        seg = jax.ops.segment_sum(train * tm[:, None], a, num_segments=nlist)
        cnt = jax.ops.segment_sum(tm, a, num_segments=nlist)
        cent = jnp.where(cnt[:, None] > 0,
                         seg / jnp.maximum(cnt[:, None], 1.0), cent)
        cnt_est = cnt * (nv.astype(jnp.float32)
                         / jnp.maximum(jnp.sum(tm), 1.0))
    pen = balance * (cnt_est / target - 1.0)
    a = assign_chunked(deq, cent, pen, 2048)
    a = jnp.where(valid, a, nlist)          # invalid rows sort past the end
    # stable sort by (bucket, row index); within-bucket rank via the
    # run-start positions (cummax of the change marks)
    skey = a * (G + 1) + g_idx
    order = jnp.argsort(skey)
    a_s = a[order]
    change = jnp.concatenate([jnp.ones((1,), bool), a_s[1:] != a_s[:-1]])
    first = jax.lax.cummax(jnp.where(change, g_idx, 0), axis=0)
    rank = g_idx - first
    valid_s = a_s < nlist
    primary = valid_s & (rank < bcap)
    NS = nlist * bcap
    slot = a_s * bcap + rank
    inv = jnp.full((NS,), -1, jnp.int32)
    inv = inv.at[jnp.where(primary, slot, NS)].set(
        jnp.where(primary, order.astype(jnp.int32), -1), mode="drop")
    # overflow rows -> leftover empty slots (count(spill) <= count(empty)
    # because NS >= G >= nv); both sides sorted ascending -> deterministic
    spill = jnp.sort(jnp.where(valid_s & ~primary,
                               order.astype(jnp.int32), G))
    empty = jnp.sort(jnp.where(inv < 0, jnp.arange(NS, dtype=jnp.int32), NS))
    npair = min(G, NS)
    ok = spill[:npair] < G
    inv = inv.at[jnp.where(ok, empty[:npair], NS)].set(
        jnp.where(ok, spill[:npair], -1), mode="drop")
    cn2 = jnp.sum(cent * cent, -1)
    return cent, cn2, inv.reshape(nlist, bcap)


def _ivf_abstract():
    cfg = EM.EdgeModelConfig()
    theta = jax.eval_shape(
        lambda k: EM.init_adaptive_layers(k, cfg), jax.random.PRNGKey(0))
    C, G = 8, 4096
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((C,) + s.shape, s.dtype), theta)
    return ((stacked,
             jax.ShapeDtypeStruct((C, G, cfg.proto_dim), jnp.float32),
             jax.ShapeDtypeStruct((C, G), jnp.float32),
             jax.ShapeDtypeStruct((C, G), jnp.int32)),
            {"nlist": 64, "bcap": 96, "iters": 4, "train_cap": 2048,
             "balance": 0.1, "backend": "ref"})


@register_program(
    "serving.index_refresh_ivf",
    abstract_args=_ivf_abstract,
    oracle="repro.serving.index.ivf_refresh_host", budget_bytes=256 << 20)
@functools.partial(jax.jit, static_argnames=(
    "nlist", "bcap", "iters", "train_cap", "balance", "backend"))
def index_refresh_ivf_program(theta, gp, gmask, gids, *, nlist: int,
                              bcap: int, iters: int, train_cap: int,
                              balance: float, backend: str = None):
    """``index_refresh_program`` + the IVF coarse quantizer, one launch:
    the flat int8 image is rebuilt exactly as in the non-IVF path (the
    exact-oracle queries keep working), then per-client k-means over the
    valid dequantized rows trains the centroids and the inverted lists
    are materialized bucket-major (codes + packed sidecar) so a probed
    bucket is one contiguous block load at query time."""
    gq, scales, gn2, mu, sd, fn = index_refresh_program(
        theta, gp, gmask, backend=backend)
    C, G, F = gq.shape
    deq = gq.astype(jnp.float32) * scales[..., None]
    cent, cn2, binv = jax.vmap(
        lambda d, m: _ivf_build_one(d, m, nlist=nlist, bcap=bcap,
                                    iters=iters, train_cap=train_cap,
                                    balance=balance))(deq, gmask)
    present = binv >= 0
    flat = jnp.maximum(binv, 0).reshape(C, nlist * bcap)
    bq = jnp.take_along_axis(gq, flat[:, :, None],
                             axis=1).reshape(C, nlist, bcap, F)
    bq = jnp.where(present[..., None], bq, 0)
    bscale = jnp.where(
        present,
        jnp.take_along_axis(scales, flat, axis=1).reshape(C, nlist, bcap),
        1.0)
    bn2 = jnp.where(
        present,
        jnp.take_along_axis(gn2, flat, axis=1).reshape(C, nlist, bcap),
        0.0)
    bids = jnp.where(
        present,
        jnp.take_along_axis(gids, flat, axis=1).reshape(C, nlist, bcap),
        -1)
    pack = jnp.stack(
        [bscale, bn2, jax.lax.bitcast_convert_type(bids, jnp.float32)],
        axis=2)
    return gq, scales, gn2, mu, sd, fn, cent, cn2, bq, pack, binv


def ivf_refresh_host(theta, gp, gmask, gids, *, nlist: int, bcap: int,
                     iters: int, train_cap: int, balance: float,
                     backend: str = None):
    """Numpy oracle for ``index_refresh_ivf_program``: the flat image via
    ``refresh_host``, then the same balanced Lloyd (same strided init,
    same penalty, same iteration count) and the same sorted placement in
    numpy. Centroids are allclose (fp reduction order differs from XLA,
    so boundary rows may flip buckets — the structural invariants, not
    bit-equal lists, are the contract); flat arrays are bit-exact."""
    del backend
    q, s, n2, mu, sd, fn = refresh_host(theta, gp, gmask)
    gids = np.asarray(gids)
    C, G, F = q.shape
    deq = q.astype(np.float32) * s[..., None]
    cents, cn2s, invs = [], [], []
    for c in range(C):
        valid = np.asarray(gmask)[c] > 0
        g_idx = np.arange(G, dtype=np.int32)
        vorder = np.argsort(np.where(valid, g_idx, G + g_idx), kind="stable")
        nv = max(int(valid.sum()), 1)
        S = min(G, train_cap)
        tpick = (np.arange(S, dtype=np.int64) * nv) // S
        train = deq[c][vorder[tpick]]
        tm = np.asarray(gmask)[c][vorder[tpick]]
        cpick = (np.arange(nlist, dtype=np.int64) * nv) // nlist
        cent = deq[c][vorder[cpick]].copy()
        target = max(nv / nlist, 1e-6)
        cnt_est = np.full(nlist, target, np.float32)
        for _ in range(iters):
            pen = balance * (cnt_est / target - 1.0)
            d = ((train * train).sum(-1)[:, None]
                 + (cent * cent).sum(-1)[None] - 2.0 * train @ cent.T)
            a = np.argmin(d + pen[None], -1)
            seg = np.zeros_like(cent)
            np.add.at(seg, a, train * tm[:, None])
            cnt = np.zeros(nlist, np.float32)
            np.add.at(cnt, a, tm)
            nz = cnt > 0
            cent[nz] = seg[nz] / cnt[nz, None]
            cnt_est = cnt * (nv / max(tm.sum(), 1.0))
        pen = balance * (cnt_est / target - 1.0)
        d = ((deq[c] * deq[c]).sum(-1)[:, None]
             + (cent * cent).sum(-1)[None] - 2.0 * deq[c] @ cent.T)
        a = np.argmin(d + pen[None], -1)
        a = np.where(valid, a, nlist)
        inv = np.full((nlist, bcap), -1, np.int32)
        spill = []
        for l in range(nlist):
            rows = np.nonzero(a == l)[0]
            inv[l, :min(len(rows), bcap)] = rows[:bcap]
            spill.extend(rows[bcap:])
        empties = np.argwhere(inv < 0)
        for r, (l, sl) in zip(sorted(spill), empties):
            inv[l, sl] = r
        cents.append(cent.astype(np.float32))
        cn2s.append((cent * cent).sum(-1).astype(np.float32))
        invs.append(inv)
    cent = np.stack(cents)
    cn2 = np.stack(cn2s)
    binv = np.stack(invs)
    present = binv >= 0
    flat = np.maximum(binv, 0).reshape(C, nlist * bcap)
    take = np.take_along_axis
    bq = np.where(present[..., None],
                  take(q, flat[:, :, None], axis=1).reshape(C, nlist, bcap, F),
                  0).astype(np.int8)
    bscale = np.where(present, take(s, flat, 1).reshape(C, nlist, bcap),
                      1.0).astype(np.float32)
    bn2 = np.where(present, take(n2, flat, 1).reshape(C, nlist, bcap),
                   0.0).astype(np.float32)
    bids = np.where(present, take(gids, flat, 1).reshape(C, nlist, bcap),
                    -1).astype(np.int32)
    pack = np.stack([bscale, bn2, bids.view(np.float32)], axis=2)
    return q, s, n2, mu, sd, fn, cent, cn2, bq, pack, binv


def refresh_host(theta, gp, gmask, *, backend: str = None):
    """Numpy oracle for ``index_refresh_program``: identical head math,
    masked BN statistics, L2 normalization, and per-row symmetric int8
    quantization (round-half-to-even, clip to ±127, scale 1.0 for empty
    rows) — allclose on dequantized rows, exact on shapes/masks."""
    del backend
    t = jax.tree_util.tree_map(np.asarray, theta)
    gp = np.asarray(gp, np.float32)
    gmask = np.asarray(gmask, np.float32)
    C, G, _ = gp.shape
    out_q, out_s, out_n2, out_mu, out_sd, out_f = [], [], [], [], [], []
    for c in range(C):
        tc = jax.tree_util.tree_map(lambda a: a[c], t)
        h = np.maximum(gp[c] @ tc["l1"]["w"] + tc["l1"]["b"], 0.0)
        f = h @ tc["l2"]["w"] + tc["l2"]["b"]
        m = gmask[c][:, None]
        n = max(float(gmask[c].sum()), 1.0)
        mu = (f * m).sum(0) / n
        sd = np.sqrt((np.square(f - mu[None, :]) * m).sum(0) / n) + 1e-5
        fn = (f - mu) / sd * tc["bn"]["scale"] + tc["bn"]["bias"]
        fn = fn / np.sqrt(np.maximum(np.sum(np.square(fn), -1,
                                            keepdims=True), _EPS))
        fn = (fn * m).astype(np.float32)
        scale = np.abs(fn).max(-1) / 127.0
        scale = np.where(scale > 0, scale, 1.0).astype(np.float32)
        q = np.clip(np.round(fn / scale[:, None]), -127, 127).astype(np.int8)
        n2 = (np.square(q.astype(np.float32)).sum(-1)
              * np.square(scale)).astype(np.float32)
        out_q.append(q); out_s.append(scale); out_n2.append(n2)
        out_mu.append(mu.astype(np.float32)); out_sd.append(sd.astype(np.float32))
        out_f.append(fn)
    return (np.stack(out_q), np.stack(out_s), np.stack(out_n2),
            np.stack(out_mu), np.stack(out_sd), np.stack(out_f))


class GalleryIndex:
    """Fixed-capacity per-client gallery with a device-resident int8 image.

    Host arrays are the source of truth (``extend`` appends rows there);
    the device image is (re)built by ``refresh(theta_stacked)`` — one
    jitted launch per head swap, no prototype re-extraction.
    """

    def __init__(self, protos: Sequence[np.ndarray], ids: Sequence[np.ndarray],
                 *, capacity: Optional[int] = None, keep_fp32: bool = True,
                 backend: Optional[str] = None, nlist=0,
                 bcap: Optional[int] = None, ivf_iters: int = 8,
                 ivf_train_cap: Optional[int] = None,
                 ivf_balance: float = 0.1):
        C = len(protos)
        if C == 0:
            raise ValueError("need at least one client")
        counts = [len(p) for p in protos]
        G = capacity if capacity is not None else max(max(counts), 1)
        if max(counts) > G:
            raise ValueError(f"capacity {G} < largest client gallery "
                             f"{max(counts)}")
        Dp = int(np.asarray(protos[0]).shape[-1])
        self.keep_fp32 = keep_fp32
        self.backend = backend
        # IVF shape parameters (compile-shape contract, like capacity):
        # nlist="auto" = sqrt(2G) centroids — per-query rows touched is
        # nlist (assign) + nprobe*bcap ~ nprobe*1.4*G/nlist (shortlist),
        # so the minimum sits above sqrt(G); sqrt(2G) keeps buckets big
        # enough for recall while shaving ~25% off the shortlist GEMM
        # vs sqrt(G) (measured at G=131072). bcap defaults to ~1.4x the
        # mean occupancy rounded up to 32 so the balance penalty keeps
        # nearly all buckets under capacity (spill stays ~0).
        if nlist == "auto":
            nlist = max(8, int(round((2 * G) ** 0.5)))
        self.nlist = int(nlist or 0)
        if self.nlist:
            if bcap is None:
                bcap = -(-int(1.4 * G / self.nlist) // 32) * 32
            self.bcap = int(bcap)
            if self.nlist * self.bcap < G:
                raise ValueError(
                    f"nlist*bcap = {self.nlist}*{self.bcap} < capacity {G}"
                    " — every row needs a slot")
            if self.nlist * (G + 1) >= 2 ** 31:
                raise ValueError("nlist*(G+1) overflows the int32 sort key")
            self.ivf_iters = int(ivf_iters)
            self.ivf_train_cap = int(ivf_train_cap
                                     if ivf_train_cap is not None
                                     else min(G, 32 * self.nlist))
            self.ivf_balance = float(ivf_balance)
        else:
            self.bcap = 0
        self.gp = np.zeros((C, G, Dp), np.float32)
        self.gids_host = np.full((C, G), -1, np.int32)
        self._fill = np.zeros((C,), np.int64)
        for c, (p, y) in enumerate(zip(protos, ids)):
            n = len(p)
            self.gp[c, :n] = np.asarray(p, np.float32)
            self.gids_host[c, :n] = np.asarray(y, np.int32)
            self._fill[c] = n
        # device image — populated by refresh()
        self.gq = self.gscale = self.gn2 = None
        self.bn_mu = self.bn_sd = self.gids = self.gf = None
        self.cent = self.cn2 = self.bq = self.pack = self.binv = None

    @property
    def n_clients(self) -> int:
        return self.gp.shape[0]

    @property
    def capacity(self) -> int:
        return self.gp.shape[1]

    @property
    def fill(self) -> List[int]:
        return [int(n) for n in self._fill]

    @property
    def has_ivf(self) -> bool:
        return self.nlist > 0 and self.cent is not None

    def resident_bytes(self, mode: str = "int8") -> int:
        """Device bytes of the queryable image (per all C clients):
        int8 = codes + scale + norm + ids; fp32 = rows + ids; ivf = the
        bucket-major codes + packed sidecar + centroids (queried INSTEAD
        of the flat image — nlist*bcap ~ 1.4*G slots at the same
        bytes/slot, plus the small coarse quantizer)."""
        C, G = self.gids_host.shape
        F = EM.EdgeModelConfig().feat_dim
        if mode == "int8":
            return C * G * (F + 4 + 4 + 4)
        if mode == "ivf":
            slots = self.nlist * self.bcap
            return C * (slots * (F + 12) + self.nlist * (4 * F + 4))
        return C * G * (4 * F + 4)

    def extend(self, client: int, protos: np.ndarray, ids: np.ndarray):
        """Append new gallery rows for one client (host side; the next
        ``refresh`` lands them on device). Raises when capacity is hit —
        capacity is a compile-shape contract, not a ring buffer."""
        n0 = int(self._fill[client])
        n = len(protos)
        if n0 + n > self.capacity:
            raise ValueError(f"client {client}: {n0}+{n} rows exceed "
                             f"capacity {self.capacity}")
        self.gp[client, n0:n0 + n] = np.asarray(protos, np.float32)
        self.gids_host[client, n0:n0 + n] = np.asarray(ids, np.int32)
        self._fill[client] = n0 + n

    def refresh(self, theta_stacked):
        """Swap in a new stacked adaptive head: rerun the head math over
        the cached prototypes and replace the resident image (including
        the IVF coarse quantizer when ``nlist > 0`` — one launch)."""
        gmask = (self.gids_host >= 0).astype(np.float32)
        self.gids = jnp.asarray(self.gids_host)
        if self.nlist:
            (gq, gscale, gn2, mu, sd, gf, cent, cn2, bq, pack,
             binv) = index_refresh_ivf_program(
                theta_stacked, jnp.asarray(self.gp), jnp.asarray(gmask),
                self.gids, nlist=self.nlist, bcap=self.bcap,
                iters=self.ivf_iters, train_cap=self.ivf_train_cap,
                balance=self.ivf_balance, backend=self.backend)
            self.cent, self.cn2 = cent, cn2
            self.bq, self.pack, self.binv = bq, pack, binv
        else:
            gq, gscale, gn2, mu, sd, gf = index_refresh_program(
                theta_stacked, jnp.asarray(self.gp), jnp.asarray(gmask),
                backend=self.backend)
        self.gq, self.gscale, self.gn2 = gq, gscale, gn2
        self.bn_mu, self.bn_sd = mu, sd
        self.gf = gf if self.keep_fp32 else None
        return self
