"""Device-resident per-client gallery index for online ReID retrieval.

Layout (all leading-axis C = clients, fixed capacity G rows per client so
every refresh/query compiles once):

  host side (the cloud's copy, never re-extracted):
    gp         (C, G, proto_dim) fp32   gallery prototypes (Eq. 1 outputs)
    gids_host  (C, G) int32             person ids, -1 = empty slot
  device side (rebuilt by ONE jitted refresh when a federated round lands
  a new adaptive head — prototypes are reused, only the head math reruns):
    gq         (C, G, feat_dim) int8    quantized L2-normalized features
    gscale     (C, G) fp32              per-ROW symmetric scale (absmax/127)
    gn2        (C, G) fp32              |dequant(row)|^2 (kernel never
                                        re-reduces the gallery)
    gids       (C, G) int32             device copy of gids_host
    bn_mu/sd   (C, feat_dim) fp32       BN statistics frozen over each
                                        client's valid gallery rows — the
                                        query featurization uses THESE, so
                                        results are batch-composition
                                        independent (see engine/batcher)
    gf         (C, G, feat_dim) fp32    optional exact fp32 rows, kept only
                                        when the index doubles as the
                                        parity/fidelity oracle

Resident bytes per row: feat_dim + 8 (int8 codes + scale + norm) vs
4*feat_dim + 8 fp32 — ~3.7x more rows in the same device budget at
feat_dim=64 (the "4x capacity" the quantize kernel buys, less the two
fp32 sidecars).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import register_program
from repro.core import edge_model as EM
from repro.kernels import ops

_EPS = 1e-12


def _l2n(x):
    return x / jnp.sqrt(jnp.maximum(jnp.sum(jnp.square(x), -1,
                                            keepdims=True), _EPS))


def _refresh_abstract():
    cfg = EM.EdgeModelConfig()
    theta = jax.eval_shape(
        lambda k: EM.init_adaptive_layers(k, cfg), jax.random.PRNGKey(0))
    C, G = 8, 4096
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((C,) + s.shape, s.dtype), theta)
    return ((stacked,
             jax.ShapeDtypeStruct((C, G, cfg.proto_dim), jnp.float32),
             jax.ShapeDtypeStruct((C, G), jnp.float32)),
            {"backend": "ref"})


@register_program(
    "serving.index_refresh",
    abstract_args=_refresh_abstract,
    oracle="repro.serving.index.refresh_host", budget_bytes=192 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def index_refresh_program(theta, gp, gmask, *, backend: str = None):
    """Rebuild the resident index under a (stacked) adaptive head:
    (C, G, proto_dim) prototypes + (C, G) validity -> int8 codes, per-row
    scales, dequantized squared norms, frozen BN stats, and the exact fp32
    rows (the caller drops those unless it keeps the parity oracle).

    Features are L2-normalized before quantization so every row shares the
    same dynamic range; empty slots are zeroed (scale 1, norm 0)."""
    f = jax.vmap(EM.adaptive_pre_bn)(theta, gp)
    mu, sd = jax.vmap(EM.adaptive_bn_stats)(f, gmask)
    fn = jax.vmap(EM.adaptive_bn_apply)(theta, f, mu, sd)
    fn = _l2n(fn) * gmask[..., None]
    C, G, F = fn.shape
    q8, scales = ops.batched_quantize(fn.reshape(C, G * F), chunk=F,
                                      backend=backend)
    gq = q8.reshape(C, G, F)
    gn2 = (jnp.sum(jnp.square(gq.astype(jnp.float32)), -1)
           * jnp.square(scales))
    return gq, scales, gn2, mu, sd, fn


def refresh_host(theta, gp, gmask, *, backend: str = None):
    """Numpy oracle for ``index_refresh_program``: identical head math,
    masked BN statistics, L2 normalization, and per-row symmetric int8
    quantization (round-half-to-even, clip to ±127, scale 1.0 for empty
    rows) — allclose on dequantized rows, exact on shapes/masks."""
    del backend
    t = jax.tree_util.tree_map(np.asarray, theta)
    gp = np.asarray(gp, np.float32)
    gmask = np.asarray(gmask, np.float32)
    C, G, _ = gp.shape
    out_q, out_s, out_n2, out_mu, out_sd, out_f = [], [], [], [], [], []
    for c in range(C):
        tc = jax.tree_util.tree_map(lambda a: a[c], t)
        h = np.maximum(gp[c] @ tc["l1"]["w"] + tc["l1"]["b"], 0.0)
        f = h @ tc["l2"]["w"] + tc["l2"]["b"]
        m = gmask[c][:, None]
        n = max(float(gmask[c].sum()), 1.0)
        mu = (f * m).sum(0) / n
        sd = np.sqrt((np.square(f - mu[None, :]) * m).sum(0) / n) + 1e-5
        fn = (f - mu) / sd * tc["bn"]["scale"] + tc["bn"]["bias"]
        fn = fn / np.sqrt(np.maximum(np.sum(np.square(fn), -1,
                                            keepdims=True), _EPS))
        fn = (fn * m).astype(np.float32)
        scale = np.abs(fn).max(-1) / 127.0
        scale = np.where(scale > 0, scale, 1.0).astype(np.float32)
        q = np.clip(np.round(fn / scale[:, None]), -127, 127).astype(np.int8)
        n2 = (np.square(q.astype(np.float32)).sum(-1)
              * np.square(scale)).astype(np.float32)
        out_q.append(q); out_s.append(scale); out_n2.append(n2)
        out_mu.append(mu.astype(np.float32)); out_sd.append(sd.astype(np.float32))
        out_f.append(fn)
    return (np.stack(out_q), np.stack(out_s), np.stack(out_n2),
            np.stack(out_mu), np.stack(out_sd), np.stack(out_f))


class GalleryIndex:
    """Fixed-capacity per-client gallery with a device-resident int8 image.

    Host arrays are the source of truth (``extend`` appends rows there);
    the device image is (re)built by ``refresh(theta_stacked)`` — one
    jitted launch per head swap, no prototype re-extraction.
    """

    def __init__(self, protos: Sequence[np.ndarray], ids: Sequence[np.ndarray],
                 *, capacity: Optional[int] = None, keep_fp32: bool = True,
                 backend: Optional[str] = None):
        C = len(protos)
        if C == 0:
            raise ValueError("need at least one client")
        counts = [len(p) for p in protos]
        G = capacity if capacity is not None else max(max(counts), 1)
        if max(counts) > G:
            raise ValueError(f"capacity {G} < largest client gallery "
                             f"{max(counts)}")
        Dp = int(np.asarray(protos[0]).shape[-1])
        self.keep_fp32 = keep_fp32
        self.backend = backend
        self.gp = np.zeros((C, G, Dp), np.float32)
        self.gids_host = np.full((C, G), -1, np.int32)
        self._fill = np.zeros((C,), np.int64)
        for c, (p, y) in enumerate(zip(protos, ids)):
            n = len(p)
            self.gp[c, :n] = np.asarray(p, np.float32)
            self.gids_host[c, :n] = np.asarray(y, np.int32)
            self._fill[c] = n
        # device image — populated by refresh()
        self.gq = self.gscale = self.gn2 = None
        self.bn_mu = self.bn_sd = self.gids = self.gf = None

    @property
    def n_clients(self) -> int:
        return self.gp.shape[0]

    @property
    def capacity(self) -> int:
        return self.gp.shape[1]

    @property
    def fill(self) -> List[int]:
        return [int(n) for n in self._fill]

    def resident_bytes(self, mode: str = "int8") -> int:
        """Device bytes of the queryable image (per all C clients):
        int8 = codes + scale + norm + ids; fp32 = rows + ids."""
        C, G = self.gids_host.shape
        F = EM.EdgeModelConfig().feat_dim
        if mode == "int8":
            return C * G * (F + 4 + 4 + 4)
        return C * G * (4 * F + 4)

    def extend(self, client: int, protos: np.ndarray, ids: np.ndarray):
        """Append new gallery rows for one client (host side; the next
        ``refresh`` lands them on device). Raises when capacity is hit —
        capacity is a compile-shape contract, not a ring buffer."""
        n0 = int(self._fill[client])
        n = len(protos)
        if n0 + n > self.capacity:
            raise ValueError(f"client {client}: {n0}+{n} rows exceed "
                             f"capacity {self.capacity}")
        self.gp[client, n0:n0 + n] = np.asarray(protos, np.float32)
        self.gids_host[client, n0:n0 + n] = np.asarray(ids, np.int32)
        self._fill[client] = n0 + n

    def refresh(self, theta_stacked):
        """Swap in a new stacked adaptive head: rerun the head math over
        the cached prototypes and replace the resident image."""
        gmask = (self.gids_host >= 0).astype(np.float32)
        gq, gscale, gn2, mu, sd, gf = index_refresh_program(
            theta_stacked, jnp.asarray(self.gp), jnp.asarray(gmask),
            backend=self.backend)
        self.gq, self.gscale, self.gn2 = gq, gscale, gn2
        self.bn_mu, self.bn_sd = mu, sd
        self.gf = gf if self.keep_fp32 else None
        self.gids = jnp.asarray(self.gids_host)
        return self
