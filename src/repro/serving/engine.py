"""Online retrieval engine: fixed-shape batched top-k over the resident index.

Query contract (shared by the int8 fast path, the fp32 exact path, and the
numpy host oracle):

  1. featurize: frozen-BN forward (``edge_model.adaptive_forward_frozen``
     with the index's ``bn_mu``/``bn_sd``) + L2 normalization — identical
     to how the gallery rows were featurized at refresh, and independent
     of batch composition;
  2. score: squared euclidean distance to every resident row (int8 path
     dequantizes via per-row scale + precomputed norms inside the
     ``batched_int8_pairwise_dist`` kernel);
  3. rank: empty slots pushed to +inf, ``lax.top_k`` on negated distances
     (ties resolve to the lowest gallery index — the same deterministic
     order as the numpy oracle's stable argsort);
  4. mask: invalid query slots (padding from the continuous batcher)
     return id -1.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import register_program, register_runtime
from repro.core import edge_model as EM
from repro.kernels import ops
from repro.obs import trace as obs
from repro.serving.index import GalleryIndex, _l2n

_PAD_DIST = 1e30
_K = 10                                    # abstract / default top-k


def _featurize(theta, bn_mu, bn_sd, qp):
    return _l2n(jax.vmap(EM.adaptive_forward_frozen)(theta, qp, bn_mu, bn_sd))


def _rank_topk(dist, gids, qmask, k):
    """(C, B, G) distances -> ((C, B, k) ids, (C, B, k) distances)."""
    C, B, _ = dist.shape
    dist = jnp.where((gids >= 0)[:, None, :], dist, _PAD_DIST)
    negd, idx = jax.lax.top_k(-dist, k)
    ids = jnp.take_along_axis(gids, idx.reshape(C, B * k),
                              axis=1).reshape(C, B, k)
    ids = jnp.where(qmask[..., None] > 0, ids, -1)
    return ids, -negd


def _query_abstract(int8: bool):
    cfg = EM.EdgeModelConfig()
    theta = jax.eval_shape(
        lambda key: EM.init_adaptive_layers(key, cfg), jax.random.PRNGKey(0))
    C, B, G = 8, 32, 4096
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((C,) + s.shape, s.dtype), theta)
    S = jax.ShapeDtypeStruct
    common = (stacked, S((C, cfg.feat_dim), jnp.float32),
              S((C, cfg.feat_dim), jnp.float32),
              S((C, B, cfg.proto_dim), jnp.float32),
              S((C, B), jnp.float32))
    if int8:
        gal = (S((C, G, cfg.feat_dim), jnp.int8), S((C, G), jnp.float32),
               S((C, G), jnp.float32), S((C, G), jnp.int32))
    else:
        gal = (S((C, G, cfg.feat_dim), jnp.float32), S((C, G), jnp.int32))
    return (common + gal, {"k": _K, "backend": "ref"})


@register_program(
    "serving.query_int8",
    abstract_args=lambda: _query_abstract(True),
    oracle="repro.serving.engine.query_host", budget_bytes=64 << 20)
@functools.partial(jax.jit, static_argnames=("k", "backend"))
def query_int8_program(theta, bn_mu, bn_sd, qp, qmask, gq, gscale, gn2,
                       gids, *, k: int, backend: str = None):
    """The serving fast path: (C, B, proto_dim) padded query batch against
    the int8 resident gallery -> top-k ids + squared distances."""
    qf = _featurize(theta, bn_mu, bn_sd, qp)
    dist = ops.batched_int8_pairwise_dist(qf, gq, gscale, gn2,
                                          backend=backend)
    return _rank_topk(dist, gids, qmask, k)


@register_program(
    "serving.query_fp32",
    abstract_args=lambda: _query_abstract(False),
    oracle="repro.serving.engine.query_host", budget_bytes=64 << 20)
@functools.partial(jax.jit, static_argnames=("k", "backend"))
def query_fp32_program(theta, bn_mu, bn_sd, qp, qmask, gf, gids, *,
                       k: int, backend: str = None):
    """Exact-path twin of ``query_int8_program`` over the fp32 rows — the
    on-device parity oracle for the int8 index (and the mAP-delta
    reference in the serve bench)."""
    qf = _featurize(theta, bn_mu, bn_sd, qp)
    dist = ops.batched_pairwise_dist(qf, gf, backend=backend)
    return _rank_topk(dist, gids, qmask, k)


def _query_ivf_abstract():
    cfg = EM.EdgeModelConfig()
    theta = jax.eval_shape(
        lambda key: EM.init_adaptive_layers(key, cfg), jax.random.PRNGKey(0))
    C, B, L, K = 8, 32, 64, 96
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((C,) + s.shape, s.dtype), theta)
    S = jax.ShapeDtypeStruct
    F = cfg.feat_dim
    return ((stacked, S((C, F), jnp.float32), S((C, F), jnp.float32),
             S((C, B, cfg.proto_dim), jnp.float32), S((C, B), jnp.float32),
             S((C, L, F), jnp.float32), S((C, L), jnp.float32),
             S((C, L, K, F), jnp.int8), S((C, L, 3, K), jnp.float32)),
            {"k": _K, "nprobe": 8, "backend": "ref"})


@register_program(
    "serving.query_ivf",
    abstract_args=_query_ivf_abstract,
    oracle="repro.serving.engine.query_ivf_host", budget_bytes=64 << 20)
@functools.partial(jax.jit,
                   static_argnames=("k", "nprobe", "backend", "with_metrics"))
def query_ivf_program(theta, bn_mu, bn_sd, qp, qmask, cent, cn2, bq, pack,
                      *, k: int, nprobe: int, backend: str = None,
                      with_metrics: bool = False):
    """The approximate serving path: featurize -> nearest ``nprobe``
    coarse buckets (``batched_cluster_assign``) -> score only those
    buckets' int8 rows (``batched_ivf_shortlist``) -> top-k. Scores
    nprobe*bcap rows per query instead of G (~sqrt(G)-fold less GEMM at
    nlist ~ sqrt(G)); distances are the same |q|^2 + |g|^2 - 2 q.g as the
    exact int8 path, so recall@k vs that path is the fidelity metric.

    ``with_metrics=True`` (the tracing specialization, registered as
    ``serving.query_ivf_metrics``) additionally returns per-client
    rows-scored counts and the probe-rank histogram of the final top-k —
    computed inside this same launch (hit mass at the last probe ranks
    means nprobe is too small for the workload)."""
    qf = _featurize(theta, bn_mu, bn_sd, qp)
    probe = ops.batched_cluster_assign(qf, cent, cn2, nprobe=nprobe,
                                       backend=backend)
    d, ids = ops.batched_ivf_shortlist(qf, probe, bq, pack, backend=backend)
    d = d + jnp.sum(jnp.square(qf), -1)[..., None]
    d = jnp.where(ids >= 0, d, _PAD_DIST)       # empty slots out of the race
    negd, idx = jax.lax.top_k(-d, k)
    top = jnp.take_along_axis(ids, idx, axis=2)
    top = jnp.where(qmask[..., None] > 0, top, -1)
    if not with_metrics:
        return top, -negd
    from repro.obs.metrics import ivf_metrics
    mets = ivf_metrics(ids, qmask, idx, bq.shape[2], nprobe)
    return top, -negd, mets


def _query_ivf_metrics_abstract():
    args, kw = _query_ivf_abstract()
    return args, {**kw, "with_metrics": True}


register_runtime(
    "serving.query_ivf_metrics",
    functools.partial(query_ivf_program, with_metrics=True),
    abstract_args=_query_ivf_metrics_abstract,
    module="repro.serving.engine",
    oracle="repro.serving.engine.query_ivf_host",
    budget_bytes=64 << 20)


def query_ivf_host(theta, bn_mu, bn_sd, qp, qmask, cent, cn2, bq, pack, *,
                   k: int, nprobe: int, backend: str = None):
    """Numpy oracle for ``query_ivf_program``: same features, nearest
    nprobe centroids by stable argsort, dequantized bucket rows scored
    exactly, empty slots masked, stable top-k."""
    del backend
    t = jax.tree_util.tree_map(np.asarray, theta)
    bn_mu, bn_sd = np.asarray(bn_mu), np.asarray(bn_sd)
    qp, qmask = np.asarray(qp, np.float32), np.asarray(qmask)
    cent, cn2 = np.asarray(cent, np.float32), np.asarray(cn2, np.float32)
    bq, pack = np.asarray(bq), np.asarray(pack, np.float32)
    C, B, _ = qp.shape
    K = bq.shape[2]
    ids = np.full((C, B, k), -1, np.int32)
    dd = np.full((C, B, k), _PAD_DIST, np.float32)
    for c in range(C):
        tc = jax.tree_util.tree_map(lambda a: a[c], t)
        h = np.maximum(qp[c] @ tc["l1"]["w"] + tc["l1"]["b"], 0.0)
        f = h @ tc["l2"]["w"] + tc["l2"]["b"]
        f = (f - bn_mu[c]) / bn_sd[c] * tc["bn"]["scale"] + tc["bn"]["bias"]
        f = f / np.sqrt(np.maximum(np.sum(np.square(f), -1, keepdims=True),
                                   1e-12))
        f = f.astype(np.float32)
        qq = np.sum(np.square(f), -1)
        dc = (qq[:, None] + cn2[c][None, :] - 2.0 * f @ cent[c].T)
        probe = np.argsort(dc, axis=1, kind="stable")[:, :nprobe]
        bids_c = pack[c, :, 2, :].view(np.int32)
        for b in range(B):
            if qmask[c, b] <= 0:
                continue
            sl_ids = bids_c[probe[b]].reshape(-1)
            blk = bq[c][probe[b]].reshape(-1, bq.shape[-1]).astype(np.float32)
            scale = pack[c, probe[b], 0, :].reshape(-1)
            n2 = pack[c, probe[b], 1, :].reshape(-1)
            d = qq[b] + n2 - 2.0 * (blk @ f[b]) * scale
            d = np.where(sl_ids >= 0, d, _PAD_DIST).astype(np.float32)
            order = np.argsort(d, kind="stable")[:k]
            ids[c, b] = sl_ids[order]
            dd[c, b] = d[order]
    return ids, dd


def recall_at_k(ids_approx: np.ndarray, ids_exact: np.ndarray,
                qmask: Optional[np.ndarray] = None) -> float:
    """Fraction of the exact path's top-k ids that the approximate path
    also returned, averaged over valid query slots — the standard ANN
    recall@k (both inputs (..., B, k) ranked id matrices, -1 = empty)."""
    a, e = np.asarray(ids_approx), np.asarray(ids_exact)
    hit = (e[..., :, None] == a[..., None, :]).any(-1) | (e < 0)
    per_q = hit.mean(-1)
    if qmask is not None:
        per_q = per_q[np.asarray(qmask) > 0]
    return float(per_q.mean())


@functools.partial(jax.jit, static_argnames=("k",))
def _naive_query_one(theta_c, mu, sd, proto, gf_c, gids_c, *, k: int):
    """One query, one client, fp32 — the per-query dispatch baseline the
    serve bench measures the batched paths against (NOT a registered fast
    path; it exists to be beaten)."""
    qf = _l2n(EM.adaptive_forward_frozen(theta_c, proto[None], mu, sd))
    dist = ops.pairwise_dist(qf, gf_c, backend="ref")[0]
    dist = jnp.where(gids_c >= 0, dist, _PAD_DIST)
    negd, idx = jax.lax.top_k(-dist, k)
    return jnp.take(gids_c, idx), -negd


def query_host(theta, bn_mu, bn_sd, qp, qmask, gf, gids, *, k: int,
               backend: str = None):
    """Numpy retrieval oracle for both registered query programs: per
    valid query slot, frozen-BN features -> exact squared distances to the
    valid fp32 gallery rows -> stable argsort -> top-k ids. Exact-match
    ground truth for the fp32 path (same fp32 feature math, same
    lowest-index tie order); allclose reference for int8."""
    del backend
    t = jax.tree_util.tree_map(np.asarray, theta)
    bn_mu, bn_sd = np.asarray(bn_mu), np.asarray(bn_sd)
    qp, qmask = np.asarray(qp, np.float32), np.asarray(qmask)
    gf, gids = np.asarray(gf, np.float32), np.asarray(gids)
    C, B, _ = qp.shape
    ids = np.full((C, B, k), -1, np.int32)
    dd = np.full((C, B, k), _PAD_DIST, np.float32)
    for c in range(C):
        tc = jax.tree_util.tree_map(lambda a: a[c], t)
        h = np.maximum(qp[c] @ tc["l1"]["w"] + tc["l1"]["b"], 0.0)
        f = h @ tc["l2"]["w"] + tc["l2"]["b"]
        f = (f - bn_mu[c]) / bn_sd[c] * tc["bn"]["scale"] + tc["bn"]["bias"]
        f = f / np.sqrt(np.maximum(np.sum(np.square(f), -1, keepdims=True),
                                   1e-12))
        f = f.astype(np.float32)
        dist = (np.sum(np.square(f), -1)[:, None]
                + np.sum(np.square(gf[c]), -1)[None, :]
                - 2.0 * (f @ gf[c].T)).astype(np.float32)
        dist[:, gids[c] < 0] = _PAD_DIST
        order = np.argsort(dist, axis=1, kind="stable")[:, :k]
        for b in range(B):
            if qmask[c, b] > 0:
                ids[c, b] = gids[c][order[b]]
                dd[c, b] = dist[b, order[b]]
    return ids, dd


def ap_from_ranked_ids(ranked_ids: np.ndarray, qid: int) -> Optional[float]:
    """Average precision of one query given its full ranked id list
    (numpy; -1 = empty slot). Same AP semantics as evalreid: precision at
    each match, averaged; None when the gallery holds no match."""
    match = np.asarray(ranked_ids) == qid
    n = int(match.sum())
    if n == 0:
        return None
    ranks = np.nonzero(match)[0] + 1
    return float(np.mean(np.arange(1, n + 1) / ranks))


def map_from_ranked_ids(ranked_ids: np.ndarray, qids: np.ndarray,
                        qmask: Optional[np.ndarray] = None) -> float:
    """mAP over a (B, k) ranked-id matrix (k spanning the whole gallery);
    queries with no gallery match (or masked out) are dropped, matching
    ``evalreid.retrieval.evaluate_retrieval``."""
    aps = []
    for b, qid in enumerate(np.asarray(qids)):
        if qmask is not None and qmask[b] <= 0:
            continue
        ap = ap_from_ranked_ids(ranked_ids[b], int(qid))
        if ap is not None:
            aps.append(ap)
    return float(np.mean(aps)) if aps else 0.0


class RetrievalEngine:
    """Online top-k retrieval over a ``GalleryIndex``.

    ``mode="int8"`` queries the quantized resident image (the exact fast
    path); ``mode="fp32"`` queries the exact rows (requires
    ``keep_fp32=True`` on the index); ``mode="ivf"`` queries only the
    ``nprobe`` nearest coarse buckets (requires ``nlist > 0`` on the
    index — the int8 path over the same index is its recall oracle).
    ``update(theta_stacked)`` is the federated integration point: when a
    round lands a new stacked adaptive head, one jitted refresh rebuilds
    the index in place — cached prototypes, no re-extraction — and
    subsequent queries see the new head.
    """

    def __init__(self, index: GalleryIndex, theta_stacked, *, k: int = _K,
                 mode: str = "int8", nprobe: int = 8,
                 backend: Optional[str] = None, refresh: bool = True):
        if mode not in ("int8", "fp32", "ivf"):
            raise ValueError(f"unknown serving mode {mode!r}")
        if mode == "fp32" and not index.keep_fp32:
            raise ValueError("fp32 mode needs keep_fp32=True on the index")
        if mode == "ivf" and not index.nlist:
            raise ValueError("ivf mode needs nlist > 0 on the index")
        self.index = index
        self.k = k
        self.mode = mode
        self.nprobe = min(int(nprobe), index.nlist) if index.nlist else 0
        self.backend = backend
        self._naive = None
        if refresh:
            self.update(theta_stacked)
        else:
            # share an already-refreshed index (e.g. several engines/modes
            # over one resident image in the serve bench)
            if index.gq is None:
                raise ValueError("refresh=False needs a refreshed index")
            self.theta = jax.tree_util.tree_map(jnp.asarray, theta_stacked)

    @classmethod
    def from_eval_cache(cls, theta_stacked, cache, t: int, *,
                        capacity: Optional[int] = None, **kw):
        """Bootstrap serving from a simulation's ``_EvalCache``: per-client
        galleries are the cache's pre-extracted prototype assembly for
        task horizon ``t`` (exactly the eval path's galleries, never
        re-extracted)."""
        protos, ids = [], []
        for c in range(cache.bench.n_clients):
            p, y = cache.host_gallery(c, t)
            protos.append(np.asarray(p))
            ids.append(np.asarray(y))
        index = GalleryIndex(protos, ids, capacity=capacity,
                             keep_fp32=kw.pop("keep_fp32", True),
                             backend=kw.get("backend"))
        return cls(index, theta_stacked, **kw)

    def update(self, theta_stacked):
        """A federated round landed: swap the head, rebuild the index."""
        self.theta = jax.tree_util.tree_map(jnp.asarray, theta_stacked)
        with obs.span("serve.index_refresh", cat="serve",
                      mode=self.mode) as sp:
            self.index.refresh(self.theta)
            sp.sync(self.index.gq)
        self._naive = None

    def extend(self, client: int, protos, ids):
        """Append gallery rows for one client and re-land the index."""
        self.index.extend(client, protos, ids)
        self.index.refresh(self.theta)
        self._naive = None

    def query_batch(self, qp, qmask, *, k: Optional[int] = None):
        """(C, B, proto_dim) padded queries + (C, B) validity -> ((C, B, k)
        ids, distances) as numpy. ONE device launch for all clients."""
        k = self.k if k is None else k
        ix = self.index
        qp = jnp.asarray(qp, jnp.float32)
        qmask = jnp.asarray(qmask, jnp.float32)
        if self.mode == "int8":
            ids, d = query_int8_program(
                self.theta, ix.bn_mu, ix.bn_sd, qp, qmask,
                ix.gq, ix.gscale, ix.gn2, ix.gids, k=k, backend=self.backend)
        elif self.mode == "ivf":
            if obs.is_active():
                # tracing specialization: same launch also returns probe
                # hit-rates + rows-scored ("serving.query_ivf_metrics")
                ids, d, mets = query_ivf_program(
                    self.theta, ix.bn_mu, ix.bn_sd, qp, qmask,
                    ix.cent, ix.cn2, ix.bq, ix.pack, k=k,
                    nprobe=self.nprobe, backend=self.backend,
                    with_metrics=True)
                obs.metric("serve.ivf", mets, nprobe=self.nprobe)
            else:
                ids, d = query_ivf_program(
                    self.theta, ix.bn_mu, ix.bn_sd, qp, qmask,
                    ix.cent, ix.cn2, ix.bq, ix.pack, k=k,
                    nprobe=self.nprobe, backend=self.backend)
        else:
            ids, d = query_fp32_program(
                self.theta, ix.bn_mu, ix.bn_sd, qp, qmask,
                ix.gf, ix.gids, k=k, backend=self.backend)
        return np.asarray(ids), np.asarray(d)

    def query_host(self, qp, qmask, *, k: Optional[int] = None):
        """The numpy oracle at this engine's current state (always fp32)."""
        if self.index.gf is None:
            raise ValueError("host oracle needs keep_fp32=True on the index")
        return query_host(self.theta, self.index.bn_mu, self.index.bn_sd,
                          qp, qmask, self.index.gf, self.index.gids,
                          k=self.k if k is None else k)

    def query_naive(self, client: int, proto, *, k: Optional[int] = None):
        """The baseline: one fp32 query, one client, one device dispatch.
        Per-client operands are pre-sliced once so the measured loop pays
        dispatch + compute, not host tree slicing."""
        if self.index.gf is None:
            raise ValueError("naive path needs keep_fp32=True on the index")
        if self._naive is None:
            C = self.index.n_clients
            self._naive = [
                (jax.tree_util.tree_map(lambda a, c=c: a[c], self.theta),
                 self.index.bn_mu[c], self.index.bn_sd[c],
                 self.index.gf[c], self.index.gids[c]) for c in range(C)]
        tc, mu, sd, gf_c, gids_c = self._naive[client]
        ids, d = _naive_query_one(tc, mu, sd, jnp.asarray(proto, jnp.float32),
                                  gf_c, gids_c, k=self.k if k is None else k)
        return np.asarray(ids), np.asarray(d)
