"""Synthetic token streams for the assigned LM architectures (smoke tests,
examples, and the end-to-end ~100M-param training driver).

A Zipf-ish unigram mixed with a deterministic n-gram structure so that a
model can actually reduce loss on it (the e2e driver checks loss decreases).
"""
from __future__ import annotations

import numpy as np


def synthetic_lm_batch(rng: np.random.Generator, batch: int, seq: int,
                       vocab: int, structure: float = 0.8):
    """Returns (tokens, labels) = (B, S) next-token pairs."""
    # zipf-like marginal
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
    # inject structure: tok[t+1] = f(tok[t]) with probability `structure`
    f = (np.arange(vocab) * 31 + 7) % vocab
    for t in range(seq):
        use = rng.random(batch) < structure
        toks[use, t + 1] = f[toks[use, t]]
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
