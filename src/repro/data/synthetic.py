"""Synthetic federated lifelong ReID benchmark.

The five real datasets (Market-1501, PKU-ReID, PersonX, Prid2011,
DukeMTMC-reID) are not available offline (repro band: data gate), so this
module simulates the paper's experimental structure:

  * a global pool of person identities, each with a base appearance vector;
  * C edge clients = non-overlapping camera views, each with a fixed
    camera transform (domain shift) plus per-round drift (a random walk on
    the transform — "camera environments are dynamic and ever-changing");
  * SPATIAL-TEMPORAL CORRELATION by construction: identities move between
    adjacent clients over rounds (a pedestrian seen at client c in round t
    tends to appear at client c+1 in round t+1) — exactly the structure
    FedSTIL's Eq. (5) relevance is designed to mine;
  * 6 sequential tasks per client, 60/40 train/query split, gallery drawn
    from *other* clients' camera views (paper §V-A.1).

All arrays are numpy, generated deterministically from the seed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class Task:
    train_x: np.ndarray     # (N_train, img_dim) raw "images"
    train_y: np.ndarray     # (N_train,) global identity ids
    query_x: np.ndarray     # (N_query, img_dim)
    query_y: np.ndarray
    client: int
    round: int


@dataclasses.dataclass
class FederatedReIDBenchmark:
    n_clients: int = 5
    n_tasks: int = 6
    img_dim: int = 256
    n_identities: int = 200
    ids_per_task: int = 24
    samples_per_id: int = 10
    train_frac: float = 0.6
    drift_scale: float = 0.15
    camera_scale: float = 0.5
    move_prob: float = 0.7       # P(identity moves to the next client)
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        C, T, D = self.n_clients, self.n_tasks, self.img_dim
        # identity appearance bases
        self.identity_base = rng.standard_normal((self.n_identities, D)).astype(np.float32)
        # per-camera (client) affine transforms
        self.cam_rot = np.stack([
            np.eye(D, dtype=np.float32)
            + self.camera_scale * rng.standard_normal((D, D)).astype(np.float32) / np.sqrt(D)
            for _ in range(C)])
        self.cam_bias = (self.camera_scale
                         * rng.standard_normal((C, D)).astype(np.float32))
        # per-round drift: random walk on a per-client bias
        drift = rng.standard_normal((C, T, D)).astype(np.float32) * self.drift_scale
        self.drift = np.cumsum(drift, axis=1)

        # identity trajectories over clients (ring movement = ST correlation)
        start = rng.integers(0, C, size=self.n_identities)
        self.location = np.zeros((T, self.n_identities), np.int64)
        loc = start.copy()
        for t in range(T):
            self.location[t] = loc
            move = rng.random(self.n_identities) < self.move_prob
            loc = (loc + move.astype(np.int64)) % C

        self._tasks: Dict[Tuple[int, int], Task] = {}
        for t in range(T):
            for c in range(C):
                self._tasks[(c, t)] = self._make_task(rng, c, t)

    # ------------------------------------------------------------------
    def _render(self, rng, ident, client, t, n):
        """n noisy views of identity `ident` under client `client`'s camera."""
        base = self.identity_base[ident]
        views = base[None] + 0.3 * rng.standard_normal(
            (n, self.img_dim)).astype(np.float32)
        x = views @ self.cam_rot[client].T + self.cam_bias[client] + self.drift[client, t]
        return x.astype(np.float32)

    def _make_task(self, rng, c, t) -> Task:
        here = np.nonzero(self.location[t] == c)[0]
        if len(here) >= self.ids_per_task:
            ids = rng.choice(here, self.ids_per_task, replace=False)
        else:  # top up with random ids (sparse rounds)
            extra = rng.choice(self.n_identities,
                               self.ids_per_task - len(here), replace=False)
            ids = np.concatenate([here, extra])
        xs, ys = [], []
        for ident in ids:
            xs.append(self._render(rng, ident, c, t, self.samples_per_id))
            ys.append(np.full((self.samples_per_id,), ident, np.int64))
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        perm = rng.permutation(len(x))
        x, y = x[perm], y[perm]
        n_train = int(len(x) * self.train_frac)
        return Task(train_x=x[:n_train], train_y=y[:n_train],
                    query_x=x[n_train:], query_y=y[n_train:],
                    client=c, round=t)

    # ------------------------------------------------------------------
    def task(self, client: int, t: int) -> Task:
        return self._tasks[(client, t)]

    def gallery(self, exclude_client: int, upto_task: int):
        """Cross-camera gallery: other clients' query splits, tasks <= t."""
        xs, ys = [], []
        for c, t in self.gallery_members(exclude_client, upto_task):
            task = self._tasks[(c, t)]
            xs.append(task.query_x)
            ys.append(task.query_y)
        return np.concatenate(xs), np.concatenate(ys)

    def gallery_members(self, exclude_client: int, upto_task: int):
        """The (client, task) keys whose query splits make up ``gallery``,
        in gallery concatenation order — lets callers assemble gallery
        prototypes from already-extracted per-task prototypes."""
        return [(c, t) for (c, t) in self._tasks
                if c != exclude_client and t <= upto_task]

    @property
    def n_classes(self) -> int:
        return self.n_identities
