from repro.data.loader import LoaderConfig, PrefetchLoader, TokenStream
from repro.data.synthetic import FederatedReIDBenchmark, Task
from repro.data.tokens import synthetic_lm_batch
