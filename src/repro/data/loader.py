"""Deterministic, host-sharded streaming loader with background prefetch.

Production substrate for the training drivers: every host in a multi-host
launch pulls only its shard of the global batch (deterministic from
(seed, step, host_id) — no coordination traffic), with a double-buffered
prefetch thread so host-side generation overlaps device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterator, Optional, Tuple

import numpy as np

from repro.data.tokens import synthetic_lm_batch


@dataclasses.dataclass
class LoaderConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    host_id: int = 0
    n_hosts: int = 1
    seed: int = 0
    prefetch: int = 2


class TokenStream:
    """Deterministic per-host shard of the synthetic LM stream.

    Batch for step s on host h is a pure function of (seed, s, h): restarts
    and elastic re-sharding reproduce the exact same data order.
    """

    def __init__(self, cfg: LoaderConfig,
                 batch_fn: Optional[Callable] = None):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self._batch_fn = batch_fn or synthetic_lm_batch

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 64 + self.cfg.host_id)
        return self._batch_fn(rng, self.local_batch, self.cfg.seq_len,
                              self.cfg.vocab_size)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Double-buffered background prefetch around any batch iterator."""

    def __init__(self, stream, prefetch: int = 2):
        self._it = iter(stream)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
