"""Pallas TPU flash-attention BACKWARD + custom_vjp wiring.

Standard two-pass scheme (Dao 2022 adapted to TPU tiling):
  pass A (per q-block):  recompute p = softmax(q kᵀ), accumulate
                         dq = (p ∘ (dp − D)) k        (D = rowsum(do ∘ o))
  pass B (per kv-block): accumulate dk = (p ∘ (dp − D))ᵀ q,  dv = pᵀ do

Both passes stream the opposite operand through VMEM with fp32 accumulators;
the forward kernel additionally stores the per-row logsumexp so the backward
never re-does the online-softmax rescaling. Validated in interpret mode
against jax.grad of the jnp oracle (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import NEG_INF

Q_BLOCK = 128
KV_BLOCK = 128


# ---------------------------------------------------------------------------
# forward that also emits the softmax stats (logsumexp per row)
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, kv_block, causal,
                scale, q_block, seq_k):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    hd = q.shape[-1]
    n_kv = seq_k // kv_block

    def body(i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(i * kv_block, kv_block), slice(None))
                    ).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(i * kv_block, kv_block), slice(None))
                    ).astype(jnp.float32)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * q_block + lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = i * kv_block + lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, -1)
        acc_new = acc * corr[:, None] + lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q.shape[0],), jnp.float32)
    a0 = jnp.zeros((q.shape[0], hd), jnp.float32)
    if causal:
        n_iter = jnp.minimum(((qi + 1) * q_block + kv_block - 1) // kv_block,
                             n_kv)
    else:
        n_iter = n_kv
    m, l, acc = lax.fori_loop(0, n_iter, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    lse_ref[...] = (m + jnp.log(jnp.maximum(l, 1e-30)))


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               kv_block, causal, scale, q_block, seq_k):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]
    delta = delta_ref[...]
    n_kv = seq_k // kv_block

    def body(i, dq):
        k = pl.load(k_ref, (pl.dslice(i * kv_block, kv_block), slice(None))
                    ).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(i * kv_block, kv_block), slice(None))
                    ).astype(jnp.float32)
        s = lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * q_block + lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = i * kv_block + lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        return dq + lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    if causal:
        n_iter = jnp.minimum(((qi + 1) * q_block + kv_block - 1) // kv_block,
                             n_kv)
    else:
        n_iter = n_kv
    dq0 = jnp.zeros_like(q)
    dq_ref[...] = lax.fori_loop(0, n_iter, body, dq0).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *, q_block, causal, scale, kv_block, seq_q):
    ki = pl.program_id(1)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    n_q = seq_q // q_block

    def body(i, carry):
        dk, dv = carry
        q = pl.load(q_ref, (pl.dslice(i * q_block, q_block), slice(None))
                    ).astype(jnp.float32)
        do = pl.load(do_ref, (pl.dslice(i * q_block, q_block), slice(None))
                     ).astype(jnp.float32)
        lse = pl.load(lse_ref, (pl.dslice(i * q_block, q_block),))
        delta = pl.load(delta_ref, (pl.dslice(i * q_block, q_block),))
        s = lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
        if causal:
            qpos = i * q_block + lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * kv_block + lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                       # (qb, kb)
        dv_new = dv + lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        dp = lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_new = dk + lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        return dk_new, dv_new

    # causal: q blocks before this kv block see nothing
    lo = (ki * kv_block) // q_block if causal else 0
    dk0 = jnp.zeros_like(k)
    dv0 = jnp.zeros_like(v)
    dk, dv = lax.fori_loop(lo, n_q, body, (dk0, dv0))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# custom_vjp op
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_vjp(q, k, v, causal=True, q_block=Q_BLOCK,
                        kv_block=KV_BLOCK, interpret=True):
    out, _ = _fwd(q, k, v, causal, q_block, kv_block, interpret)
    return out


def _fwd(q, k, v, causal, q_block, kv_block, interpret):
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    scale = 1.0 / math.sqrt(hd)
    qf, kf, vf = (t.reshape(B * H, t.shape[2], hd) for t in (q, k, v))
    kern = functools.partial(_fwd_kernel, kv_block=kv_block, causal=causal,
                             scale=scale, q_block=q_block, seq_k=Sk)
    out, lse = pl.pallas_call(
        kern,
        grid=(B * H, Sq // q_block),
        in_specs=[
            pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, q_block), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd), lse


def _fwd_rule(q, k, v, causal, q_block, kv_block, interpret):
    out, lse = _fwd(q, k, v, causal, q_block, kv_block, interpret)
    return out, (q, k, v, out, lse)


def _bwd_rule(causal, q_block, kv_block, interpret, res, do):
    q, k, v, out, lse = res
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    scale = 1.0 / math.sqrt(hd)
    delta = jnp.sum(out.astype(jnp.float32) * do.astype(jnp.float32), -1)
    qf, kf, vf, dof = (t.reshape(B * H, t.shape[2], hd)
                       for t in (q, k, v, do))
    deltaf = delta.reshape(B * H, Sq)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, kv_block=kv_block, causal=causal,
                          scale=scale, q_block=q_block, seq_k=Sk),
        grid=(B * H, Sq // q_block),
        in_specs=[
            pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, q_block), lambda b, i: (b, i)),
            pl.BlockSpec((None, q_block), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, dof, lse, deltaf)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, q_block=q_block, causal=causal,
                          scale=scale, kv_block=kv_block, seq_q=Sq),
        grid=(B * H, Sk // kv_block),
        in_specs=[
            pl.BlockSpec((None, Sq, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, kv_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, kv_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Sq, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Sq), lambda b, i: (b, 0)),
            pl.BlockSpec((None, Sq), lambda b, i: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, kv_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, kv_block, hd), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sk, hd), k.dtype),
            jax.ShapeDtypeStruct((B * H, Sk, hd), v.dtype),
        ],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, deltaf)

    rs = lambda t: t.reshape(B, H, t.shape[1], hd)
    return rs(dq), rs(dk), rs(dv)


flash_attention_vjp.defvjp(_fwd_rule, _bwd_rule)
