"""Pallas TPU kernel: fp32 queries x int8-quantized gallery distances.

The serving hot spot (repro/serving): the resident retrieval index holds
every gallery row as int8 with one fp32 scale per row (~4x the rows of an
fp32 index under the same device-memory budget), and query batches arrive
fp32. Each grid step dequantizes one (g_block, F) int8 tile in VMEM and
runs the same |q|^2 + |g|^2 - 2 q.g tile math as kernels/pairwise_dist on
the MXU — int8 buys HBM capacity and bandwidth; the accumulate stays fp32.
Squared norms of the DEQUANTIZED rows are precomputed once at index-refresh
time and passed in, so the kernel never re-reduces the gallery:

    dist[c, b, g] = |q[c, b]|^2 + gn2[c, g] - 2 * scale[c, g] * (q . gq[c, g])
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.compat import default_interpret

B_BLOCK = 128
G_BLOCK = 128


def _i8dist_kernel(q_ref, g_ref, s_ref, n2_ref, o_ref):
    q = q_ref[0].astype(jnp.float32)            # (bb, F)
    g = g_ref[0].astype(jnp.float32)            # (gb, F) int8 -> f32 in VMEM
    s = s_ref[0]                                # (gb,) per-row scales
    n2 = n2_ref[0]                              # (gb,) dequantized |g|^2
    qq = jnp.sum(q * q, -1, keepdims=True)      # (bb, 1)
    dot = jax.lax.dot_general(q, g, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[0] = qq + n2[None, :] - 2.0 * (dot * s[None, :])


def batched_int8_pairwise_dist(q, gq, gscale, gn2, *,
                               b_block: int = B_BLOCK,
                               g_block: int = G_BLOCK,
                               interpret: Optional[bool] = None):
    """(C, B, F) fp32 x ((C, G, F) int8, (C, G) scales, (C, G) sq-norms)
    -> (C, B, G) fp32 squared distances to the dequantized gallery rows.

    One client per leading grid step (the serving layout: every client's
    query batch scores its own resident gallery in a single launch). B, G
    padded to block multiples internally.
    """
    if interpret is None:
        interpret = default_interpret()
    C, B, F = q.shape
    G = gq.shape[1]
    b_block = min(b_block, max(8, B))
    g_block = min(g_block, max(8, G))
    Bp = (B + b_block - 1) // b_block * b_block
    Gp = (G + g_block - 1) // g_block * g_block
    qp = jnp.pad(q, ((0, 0), (0, Bp - B), (0, 0)))
    gp = jnp.pad(gq, ((0, 0), (0, Gp - G), (0, 0)))
    sp = jnp.pad(gscale, ((0, 0), (0, Gp - G)))
    np_ = jnp.pad(gn2, ((0, 0), (0, Gp - G)))

    out = pl.pallas_call(
        _i8dist_kernel,
        grid=(C, Bp // b_block, Gp // g_block),
        in_specs=[
            pl.BlockSpec((1, b_block, F), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, g_block, F), lambda c, i, j: (c, j, 0)),
            pl.BlockSpec((1, g_block), lambda c, i, j: (c, j)),
            pl.BlockSpec((1, g_block), lambda c, i, j: (c, j)),
        ],
        out_specs=pl.BlockSpec((1, b_block, g_block),
                               lambda c, i, j: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, Bp, Gp), jnp.float32),
        interpret=interpret,
    )(qp, gp, sp, np_)
    return out[:, :B, :G]
