"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are also the implementations the CPU benchmarks and the dry-run HLO
use (identical math, no pallas_call in the lowered program).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q: (B,H,Sq,hd), k/v: (B,H,Sk,hd) -> (B,H,Sq,hd). fp32 softmax."""
    hd = q.shape[-1]
    s = scale if scale is not None else 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * s
    if causal:
        Sq, Sk = logits.shape[-2], logits.shape[-1]
        mask = jnp.arange(Sk)[None, :] <= (jnp.arange(Sq)[:, None] + (Sk - Sq))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def pairwise_dist_ref(q, g):
    """Squared euclidean distances: (Q,D) x (G,D) -> (Q,G), fp32."""
    q = q.astype(jnp.float32)
    g = g.astype(jnp.float32)
    qq = jnp.sum(q * q, -1, keepdims=True)
    gg = jnp.sum(g * g, -1)
    return qq + gg[None, :] - 2.0 * (q @ g.T)


def batched_pairwise_dist_ref(q, g):
    """Per-client squared euclidean: (C,Q,D) x (C,G,D) -> (C,Q,G), fp32."""
    q = q.astype(jnp.float32)
    g = g.astype(jnp.float32)
    qq = jnp.sum(q * q, -1)[:, :, None]
    gg = jnp.sum(g * g, -1)[:, None, :]
    return qq + gg - 2.0 * jnp.einsum("cqd,cgd->cqg", q, g)


def batched_int8_pairwise_dist_ref(q, gq, gscale, gn2):
    """fp32 queries vs an int8-quantized resident gallery (the serving
    index layout): (C, B, F) x ((C, G, F) int8, (C, G) per-row scales,
    (C, G) dequantized squared norms) -> (C, B, G) squared distances to
    the dequantized rows. One-way int8 -> f32 dequant (no round-trip)."""
    q = q.astype(jnp.float32)
    qq = jnp.sum(q * q, -1)[:, :, None]
    dot = jnp.einsum("cbf,cgf->cbg", q, gq.astype(jnp.float32))
    return qq + gn2[:, None, :] - 2.0 * (dot * gscale[:, None, :])


def adaptive_combine_ref(base, alpha, a):
    """FedSTIL Eq. 2: theta = B ⊙ alpha + A (elementwise, any shape)."""
    return base * alpha + a


def relevance_aggregate_ref(w, thetas):
    """FedSTIL Eq. 6: (C,C) x (C,P) -> (C,P), fp32 accumulate."""
    return (w.astype(jnp.float32) @ thetas.astype(jnp.float32)).astype(thetas.dtype)


def fused_relevance_aggregate_ref(w, thetas):
    """Fused FedSTIL server math (Eq. 5 post-processing + Eq. 6):

        Wm = w ⊙ (1 - I)                 (no self-relevance)
        Wn = Wm / rowsum(Wm)             (zero rows stay zero)
        B  = Wn @ thetas                 (fp32 accumulate)

    w: (C, C) *raw* decayed relevance (diagonal may hold junk);
    thetas: (C, P). Returns (B: (C, P) in thetas.dtype, Wn: (C, C) fp32).
    """
    C = w.shape[0]
    wm = w.astype(jnp.float32) * (1.0 - jnp.eye(C, dtype=jnp.float32))
    rows = jnp.sum(wm, axis=1, keepdims=True)
    wn = jnp.where(rows > 0, wm / jnp.where(rows > 0, rows, 1.0), 0.0)
    b = (wn @ thetas.astype(jnp.float32)).astype(thetas.dtype)
    return b, wn


def batched_quantize_ref(x, *, chunk: int = 256):
    """Per-chunk symmetric int8 quantization of stacked payload rows:
    (C, P) fp32 -> ((C, P) int8, (C, ceil(P/chunk)) fp32 scales). Chunks of
    ``chunk`` contiguous elements share one scale = absmax/127 (1.0 for
    all-zero chunks); round-half-to-even, clip to [-127, 127]."""
    C, P = x.shape
    nc = (P + chunk - 1) // chunk
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, nc * chunk - P)))
    xc = xp.reshape(C, nc, chunk)
    absmax = jnp.max(jnp.abs(xc), axis=2, keepdims=True)
    scale = absmax / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)   # all-zero / subnormal chunks
    q = jnp.clip(jnp.round(xc / scale), -127.0, 127.0).astype(jnp.int8)
    return q.reshape(C, nc * chunk)[:, :P], scale[..., 0]


def batched_dequantize_ref(q, scales, *, chunk: int = 256):
    """Inverse of ``batched_quantize_ref``: (C, P) int8 + per-chunk scales
    -> (C, P) fp32."""
    C, P = q.shape
    nc = scales.shape[1]
    qp = jnp.pad(q, ((0, 0), (0, nc * chunk - P))).astype(jnp.float32)
    out = qp.reshape(C, nc, chunk) * scales[..., None]
    return out.reshape(C, nc * chunk)[:, :P]


def grouped_topk_rank_ref(x, *, group: int):
    """Exact within-group magnitude ranks for stacked rows.

    x: (C, P) (P padded to a group multiple by the callers) viewed as
    groups of ``group`` contiguous elements; returns (C, P//group, group)
    int32 ranks, 0 = largest magnitude. Ties broken by lowest index, so
    ranks are a permutation of 0..group-1 — the counting form (an 8x8
    broadcast compare, no sort / no scatter / no cumsum) is what makes
    top-k selection fast on every backend, and the deterministic
    semantics every implementation (numpy host codec, this oracle, the
    Pallas kernel) shares bit-for-bit."""
    C, P = x.shape
    nb = P // group
    a = jnp.abs(x.astype(jnp.float32)).reshape(C, nb, group)
    ai = a[..., :, None]                                   # rank of i ...
    aj = a[..., None, :]                                   # ... vs every j
    ii = jnp.arange(group)
    beats = jnp.logical_or(aj > ai,
                           jnp.logical_and(aj == ai,
                                           ii[None, :] < ii[:, None]))
    return jnp.sum(beats.astype(jnp.int32), axis=-1)       # (C, nb, group)


def batched_topk_pack_ref(x, *, group: int, kg: int):
    """Grouped top-k sparsify+pack: (C, P) -> (values (C, nb*kg) fp32,
    indices (C, nb*kg) int32) where nb = ceil(P/group) and every group of
    ``group`` contiguous elements keeps its ``kg`` largest magnitudes
    (ties by lowest index), packed in magnitude-rank order.

    The group-local budget is the device-friendly form of top-k: selection
    is an O(group^2) counting compare and packing is a one-hot reduction —
    no global sort, no scatter — while delta/error-feedback encoding (see
    comm.codec) makes the uniform per-group budget self-correcting."""
    C, P = x.shape
    nb = (P + group - 1) // group
    Pp = nb * group
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, Pp - P)))
    rank = grouped_topk_rank_ref(xp, group=group)          # (C, nb, G)
    xg = xp.reshape(C, nb, group)
    onehot = (rank[..., None] ==
              jnp.arange(kg)[None, None, None, :])         # (C, nb, G, kg)
    oh = onehot.astype(jnp.float32)
    vals = jnp.sum(xg[..., None] * oh, axis=2)             # (C, nb, kg)
    gidx = (jnp.arange(nb, dtype=jnp.int32)[:, None] * group
            + jnp.arange(group, dtype=jnp.int32)[None, :])  # (nb, G)
    idx = jnp.sum(gidx[None, :, :, None] * onehot.astype(jnp.int32), axis=2)
    return vals.reshape(C, nb * kg), idx.reshape(C, nb * kg)


def batched_topk_unpack_ref(vals, idx, *, p: int, group: int, kg: int):
    """Inverse of ``batched_topk_pack_ref``: (C, nb*kg) values + indices
    -> dense (C, p) fp32 (dropped entries zero). One-hot reduction per
    group — scatter-free like the pack."""
    C, K = vals.shape
    nb = K // kg
    vb = vals.astype(jnp.float32).reshape(C, nb, kg)
    li = (idx.reshape(C, nb, kg)
          - (jnp.arange(nb, dtype=jnp.int32) * group)[None, :, None])
    onehot = (li[..., None] ==
              jnp.arange(group, dtype=jnp.int32)[None, None, None, :])
    dense = jnp.sum(vb[..., None] * onehot.astype(jnp.float32), axis=2)
    return dense.reshape(C, nb * group)[:, :p]


def batched_idx_bitpack_ref(idx, *, group: int, kg: int):
    """Bit-pack grouped top-k indices: (C, K) int32 absolute indices from
    ``batched_topk_pack`` -> (C, bits * ceil(K/8)) uint8, where
    bits = ceil(log2(group)) (3 at group=8 — a 10.7x shrink vs int32).

    Each slot s of the K = nb*kg pack slots belongs to group s // kg, so
    only the LOCAL index li = idx - (s // kg) * group (0..group-1) carries
    information; the absolute index is reconstructed from the slot
    position. Layout is bitplane-major: plane j holds bit j of every
    slot's li, 8 slots per byte (slot s -> byte s // 8, bit s % 8), planes
    concatenated along the last axis — byte lanes, plain shift/mask ALU
    ops, no gather/scatter. Padding slots (K up to a byte multiple)
    carry li = 0 and are sliced off by the unpack."""
    C, K = idx.shape
    bits = (group - 1).bit_length()
    kb = (K + 7) // 8
    slot = jnp.arange(K, dtype=jnp.int32)
    li = idx.astype(jnp.int32) - (slot // kg)[None, :] * group
    lip = jnp.pad(li, ((0, 0), (0, kb * 8 - K)))
    lib = lip.reshape(C, kb, 8)
    lane = jnp.left_shift(jnp.int32(1), jnp.arange(8, dtype=jnp.int32))
    planes = [jnp.sum(((lib >> j) & 1) * lane, axis=2) for j in range(bits)]
    return jnp.concatenate(planes, axis=1).astype(jnp.uint8)


def batched_idx_bitunpack_ref(packed, *, k: int, group: int, kg: int):
    """Inverse of ``batched_idx_bitpack_ref``: (C, bits * ceil(k/8)) uint8
    bitplanes -> (C, k) int32 absolute indices (slot s's group base
    (s // kg) * group plus the unpacked local index)."""
    C = packed.shape[0]
    bits = (group - 1).bit_length()
    kb = packed.shape[1] // bits
    b = packed.reshape(C, bits, kb).astype(jnp.int32)
    lanes = ((b[..., None] >> jnp.arange(8, dtype=jnp.int32)) & 1)
    planes = lanes.reshape(C, bits, kb * 8)[:, :, :k]
    shift = jnp.arange(bits, dtype=jnp.int32)[None, :, None]
    li = jnp.sum(jnp.left_shift(planes, shift), axis=1)
    slot = jnp.arange(k, dtype=jnp.int32)
    return (slot // kg)[None, :] * group + li


def batched_cluster_assign_ref(qf, cent, cn2, *, nprobe: int):
    """IVF coarse-quantizer probe selection: (C, B, F) queries x
    ((C, L, F) centroids, (C, L) sq-norms) -> (C, B, nprobe) int32 bucket
    ids, nearest first (``lax.top_k`` ties resolve to the lowest id —
    shared with the Pallas dispatcher and the numpy host oracle)."""
    q = qf.astype(jnp.float32)
    qq = jnp.sum(q * q, -1)
    dc = (qq[..., None] + cn2[:, None, :]
          - 2.0 * jnp.einsum("cbf,clf->cbl", q, cent.astype(jnp.float32)))
    return jax.lax.top_k(-dc, nprobe)[1]


def batched_ivf_shortlist_ref(qf, probe, bq, pack):
    """Score the probed buckets of the bucket-major int8 image:
    (C, B, F) queries + (C, B, P) probe ids x ((C, L, K, F) int8 rows,
    (C, L, 3, K) packed [scale; |g|^2; id-bitcast] sidecar) ->
    ((C, B, P*K) partial squared distances |g|^2 - 2 q.g, (C, B, P*K)
    int32 row ids, -1 for empty slots). The caller adds |q|^2 and masks
    ids < 0 before ranking.

    Formulation: ``lax.scan`` over the flattened C*B query stream with
    one contiguous ``dynamic_slice`` per probe for the bucket block and
    one for the packed sidecar. On XLA CPU this is the measured-fast
    shape — slice + (K, F) dequant matvec beats every gather variant
    ~2x at G=131k because gathers lower to per-element loads while
    slices stay memcpy-like (see benchmarks/BENCH_serve_round.json)."""
    C, B, F = qf.shape
    P = probe.shape[-1]
    K = bq.shape[2]
    q2 = qf.astype(jnp.float32).reshape(C * B, F)
    pf = probe.reshape(C * B, P)
    cidx = jnp.repeat(jnp.arange(C, dtype=jnp.int32), B)

    def step(_, inp):
        qi, pi, ci = inp
        ds, ids = [], []
        for j in range(P):
            blk = jax.lax.dynamic_slice(bq, (ci, pi[j], 0, 0),
                                        (1, 1, K, F))[0, 0]
            pk = jax.lax.dynamic_slice(pack, (ci, pi[j], 0, 0),
                                       (1, 1, 3, K))[0, 0]
            dot = blk.astype(jnp.float32) @ qi
            ds.append(pk[1] - 2.0 * (dot * pk[0]))
            ids.append(jax.lax.bitcast_convert_type(pk[2], jnp.int32))
        return None, (jnp.concatenate(ds), jnp.concatenate(ids))

    _, (d, ids) = jax.lax.scan(step, None, (q2, pf, cidx))
    return d.reshape(C, B, P * K), ids.reshape(C, B, P * K)


def kl_similarity_ref(a, b):
    """exp(-KL(softmax(a_i) || softmax(b_j))): (N,D) x (M,D) -> (N,M)."""
    p = jax.nn.softmax(a.astype(jnp.float32), -1)
    logp = jax.nn.log_softmax(a.astype(jnp.float32), -1)
    logq = jax.nn.log_softmax(b.astype(jnp.float32), -1)
    h = jnp.sum(p * logp, -1)                    # (N,)
    cross = p @ logq.T                            # (N,M)
    return jnp.exp(-(h[:, None] - cross))
