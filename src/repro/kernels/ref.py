"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are also the implementations the CPU benchmarks and the dry-run HLO
use (identical math, no pallas_call in the lowered program).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q: (B,H,Sq,hd), k/v: (B,H,Sk,hd) -> (B,H,Sq,hd). fp32 softmax."""
    hd = q.shape[-1]
    s = scale if scale is not None else 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * s
    if causal:
        Sq, Sk = logits.shape[-2], logits.shape[-1]
        mask = jnp.arange(Sk)[None, :] <= (jnp.arange(Sq)[:, None] + (Sk - Sq))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def pairwise_dist_ref(q, g):
    """Squared euclidean distances: (Q,D) x (G,D) -> (Q,G), fp32."""
    q = q.astype(jnp.float32)
    g = g.astype(jnp.float32)
    qq = jnp.sum(q * q, -1, keepdims=True)
    gg = jnp.sum(g * g, -1)
    return qq + gg[None, :] - 2.0 * (q @ g.T)


def batched_pairwise_dist_ref(q, g):
    """Per-client squared euclidean: (C,Q,D) x (C,G,D) -> (C,Q,G), fp32."""
    q = q.astype(jnp.float32)
    g = g.astype(jnp.float32)
    qq = jnp.sum(q * q, -1)[:, :, None]
    gg = jnp.sum(g * g, -1)[:, None, :]
    return qq + gg - 2.0 * jnp.einsum("cqd,cgd->cqg", q, g)


def adaptive_combine_ref(base, alpha, a):
    """FedSTIL Eq. 2: theta = B ⊙ alpha + A (elementwise, any shape)."""
    return base * alpha + a


def relevance_aggregate_ref(w, thetas):
    """FedSTIL Eq. 6: (C,C) x (C,P) -> (C,P), fp32 accumulate."""
    return (w.astype(jnp.float32) @ thetas.astype(jnp.float32)).astype(thetas.dtype)


def fused_relevance_aggregate_ref(w, thetas):
    """Fused FedSTIL server math (Eq. 5 post-processing + Eq. 6):

        Wm = w ⊙ (1 - I)                 (no self-relevance)
        Wn = Wm / rowsum(Wm)             (zero rows stay zero)
        B  = Wn @ thetas                 (fp32 accumulate)

    w: (C, C) *raw* decayed relevance (diagonal may hold junk);
    thetas: (C, P). Returns (B: (C, P) in thetas.dtype, Wn: (C, C) fp32).
    """
    C = w.shape[0]
    wm = w.astype(jnp.float32) * (1.0 - jnp.eye(C, dtype=jnp.float32))
    rows = jnp.sum(wm, axis=1, keepdims=True)
    wn = jnp.where(rows > 0, wm / jnp.where(rows > 0, rows, 1.0), 0.0)
    b = (wn @ thetas.astype(jnp.float32)).astype(thetas.dtype)
    return b, wn


def kl_similarity_ref(a, b):
    """exp(-KL(softmax(a_i) || softmax(b_j))): (N,D) x (M,D) -> (N,M)."""
    p = jax.nn.softmax(a.astype(jnp.float32), -1)
    logp = jax.nn.log_softmax(a.astype(jnp.float32), -1)
    logq = jax.nn.log_softmax(b.astype(jnp.float32), -1)
    h = jnp.sum(p * logp, -1)                    # (N,)
    cross = p @ logq.T                            # (N,M)
    return jnp.exp(-(h[:, None] - cross))
