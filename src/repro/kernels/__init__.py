"""Pallas TPU kernels for the paper's compute hot spots + jnp oracles.

kernels: flash_attention (backbone prefill), pairwise_dist (ReID retrieval),
adaptive_combine (Eq. 2), relevance_aggregate (Eq. 6), kl_similarity (Eq. 4).
Each has a pl.pallas_call + BlockSpec implementation validated in
interpret=True mode against the pure-jnp oracle in ref.py.
"""
from repro.kernels.ops import (
    adaptive_combine,
    flash_attention,
    kl_similarity,
    pairwise_dist,
    relevance_aggregate,
)
