"""Pallas TPU kernel: pairwise KL task-similarity (paper Eq. 4)

    S[i, j] = exp(-KL(softmax(a_i) || softmax(b_j)))
            = exp(-(Σ p_i log p_i − p_i · log q_j))

The cross term is a matmul (MXU); row entropies are computed once per
a-block. Tiles (n_block x D) x (m_block x D) -> (n_block x m_block).
At production scale this runs over the full spatial-temporal task-feature
history on the parameter server every round.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.compat import default_interpret

N_BLOCK = 128
M_BLOCK = 128


def _kl_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    a = a - jnp.max(a, -1, keepdims=True)
    b = b - jnp.max(b, -1, keepdims=True)
    logp = a - jnp.log(jnp.sum(jnp.exp(a), -1, keepdims=True))
    logq = b - jnp.log(jnp.sum(jnp.exp(b), -1, keepdims=True))
    p = jnp.exp(logp)
    h = jnp.sum(p * logp, -1)                    # (nb,)
    cross = jax.lax.dot_general(p, logq, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[...] = jnp.exp(-(h[:, None] - cross))


def kl_similarity(a, b, *, n_block: int = N_BLOCK, m_block: int = M_BLOCK,
                  interpret: Optional[bool] = None):
    """a: (N, D), b: (M, D) -> (N, M) fp32 similarities in (0, 1]."""
    if interpret is None:
        interpret = default_interpret()
    N, D = a.shape
    M = b.shape[0]
    n_block = min(n_block, max(8, N))
    m_block = min(m_block, max(8, M))
    Np = (N + n_block - 1) // n_block * n_block
    Mp = (M + m_block - 1) // m_block * m_block
    ap = jnp.pad(a, ((0, Np - N), (0, 0)))
    bp = jnp.pad(b, ((0, Mp - M), (0, 0)))

    out = pl.pallas_call(
        _kl_kernel,
        grid=(Np // n_block, Mp // m_block),
        in_specs=[
            pl.BlockSpec((n_block, D), lambda i, j: (i, 0)),
            pl.BlockSpec((m_block, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((n_block, m_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Mp), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:N, :M]
