"""Pallas TPU kernel: batched per-chunk int8 quantization (wire codec).

The comm subsystem's quantize stage maps every client's flattened upload
row to int8 with one fp32 scale per ``chunk`` contiguous elements:

    scale[c, j] = max(|x[c, j*chunk:(j+1)*chunk]|) / 127     (0 -> 1.0)
    q[c, i]     = clip(round(x[c, i] / scale), -127, 127)

x: (C, P) stacked client payloads -> (q: (C, P) int8, scales: (C, ceil(P /
chunk)) fp32). One grid step quantizes a (1, p_block) tile (p_block is a
multiple of ``chunk``, so every chunk's absmax lives in VMEM with its
data); all C clients' uploads are encoded in a single launch before any
host readback. Rounding is round-half-to-even (deterministic, matches the
numpy host codec bit-for-bit on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.compat import default_interpret

CHUNK = 256
P_BLOCK = 2048


def _block_for(chunk: int, p: int) -> int:
    """Largest chunk-multiple block <= P_BLOCK (at least one chunk)."""
    return chunk * max(1, min(P_BLOCK, p) // chunk)


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)              # (1, pb)
    nc = s_ref.shape[1]
    xc = x.reshape(nc, -1)                          # (nc, chunk)
    absmax = jnp.max(jnp.abs(xc), axis=1, keepdims=True)
    scale = absmax / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)   # all-zero / subnormal chunks
    q = jnp.clip(jnp.round(xc / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8).reshape(1, -1)
    s_ref[...] = scale.reshape(1, nc)


def batched_quantize(x, *, chunk: int = CHUNK,
                     interpret: Optional[bool] = None):
    """(C, P) fp32 -> ((C, P) int8, (C, ceil(P/chunk)) fp32 scales)."""
    if interpret is None:
        interpret = default_interpret()
    C, P = x.shape
    nc = (P + chunk - 1) // chunk
    pb = _block_for(chunk, P)
    Pp = (P + pb - 1) // pb * pb
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, Pp - P)))

    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(C, Pp // pb),
        in_specs=[pl.BlockSpec((1, pb), lambda c, j: (c, j))],
        out_specs=[
            pl.BlockSpec((1, pb), lambda c, j: (c, j)),
            pl.BlockSpec((1, pb // chunk), lambda c, j: (c, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, Pp), jnp.int8),
            jax.ShapeDtypeStruct((C, Pp // chunk), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return q[:, :P], s[:, :nc]


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)              # (1, pb)
    nc = s_ref.shape[1]
    s = s_ref[...].reshape(nc, 1)
    o_ref[...] = (q.reshape(nc, -1) * s).reshape(1, -1)


def batched_dequantize(q, scales, *, chunk: int = CHUNK,
                       interpret: Optional[bool] = None):
    """Inverse of ``batched_quantize``: (C, P) int8 + scales -> (C, P) fp32."""
    if interpret is None:
        interpret = default_interpret()
    C, P = q.shape
    pb = _block_for(chunk, P)
    Pp = (P + pb - 1) // pb * pb
    qp = jnp.pad(q, ((0, 0), (0, Pp - P)))
    sp = jnp.pad(scales, ((0, 0), (0, Pp // chunk - scales.shape[1])),
                 constant_values=1.0)

    out = pl.pallas_call(
        _dequant_kernel,
        grid=(C, Pp // pb),
        in_specs=[
            pl.BlockSpec((1, pb), lambda c, j: (c, j)),
            pl.BlockSpec((1, pb // chunk), lambda c, j: (c, j)),
        ],
        out_specs=pl.BlockSpec((1, pb), lambda c, j: (c, j)),
        out_shape=jax.ShapeDtypeStruct((C, Pp), jnp.float32),
        interpret=interpret,
    )(qp, sp)
    return out[:, :P]
