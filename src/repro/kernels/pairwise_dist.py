"""Pallas TPU kernel: query x gallery squared-euclidean distance matrix.

This is the ReID retrieval hot spot (paper §V: every evaluation round ranks
a cross-camera gallery for every query). dist = |q|² + |g|² − 2·q·gᵀ with
the inner product on the MXU; tiles (q_block x D) x (g_block x D).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.compat import default_interpret

Q_BLOCK = 128
G_BLOCK = 128


def _dist_kernel(q_ref, g_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)          # (qb, D)
    g = g_ref[...].astype(jnp.float32)          # (gb, D)
    qq = jnp.sum(q * q, -1, keepdims=True)      # (qb, 1)
    gg = jnp.sum(g * g, -1)                     # (gb,)
    dot = jax.lax.dot_general(q, g, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = qq + gg[None, :] - 2.0 * dot


def pairwise_dist(q, g, *, q_block: int = Q_BLOCK, g_block: int = G_BLOCK,
                  interpret: Optional[bool] = None):
    """(Q, D) x (G, D) -> (Q, G) fp32 squared distances. Q, G padded to
    block multiples internally."""
    if interpret is None:
        interpret = default_interpret()
    Q, D = q.shape
    G = g.shape[0]
    q_block = min(q_block, max(8, Q))
    g_block = min(g_block, max(8, G))
    Qp = (Q + q_block - 1) // q_block * q_block
    Gp = (G + g_block - 1) // g_block * g_block
    qp = jnp.pad(q, ((0, Qp - Q), (0, 0)))
    gp = jnp.pad(g, ((0, Gp - G), (0, 0)))

    out = pl.pallas_call(
        _dist_kernel,
        grid=(Qp // q_block, Gp // g_block),
        in_specs=[
            pl.BlockSpec((q_block, D), lambda i, j: (i, 0)),
            pl.BlockSpec((g_block, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((q_block, g_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Gp), jnp.float32),
        interpret=interpret,
    )(qp, gp)
    return out[:Q, :G]


def _bdist_kernel(q_ref, g_ref, o_ref):
    q = q_ref[0].astype(jnp.float32)            # (qb, D)
    g = g_ref[0].astype(jnp.float32)            # (gb, D)
    qq = jnp.sum(q * q, -1, keepdims=True)      # (qb, 1)
    gg = jnp.sum(g * g, -1)                     # (gb,)
    dot = jax.lax.dot_general(q, g, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[0] = qq + gg[None, :] - 2.0 * dot


def batched_pairwise_dist(q, g, *, q_block: int = Q_BLOCK,
                          g_block: int = G_BLOCK,
                          interpret: Optional[bool] = None):
    """(C, Q, D) x (C, G, D) -> (C, Q, G) fp32 squared distances.

    The batched-eval layout: one client per leading grid step, so evaluating
    all C clients' query-vs-gallery distance matrices is a single kernel
    launch instead of C ``pairwise_dist`` dispatches. Q, G padded to block
    multiples internally.
    """
    if interpret is None:
        interpret = default_interpret()
    C, Q, D = q.shape
    G = g.shape[1]
    q_block = min(q_block, max(8, Q))
    g_block = min(g_block, max(8, G))
    Qp = (Q + q_block - 1) // q_block * q_block
    Gp = (G + g_block - 1) // g_block * g_block
    qp = jnp.pad(q, ((0, 0), (0, Qp - Q), (0, 0)))
    gp = jnp.pad(g, ((0, 0), (0, Gp - G), (0, 0)))

    out = pl.pallas_call(
        _bdist_kernel,
        grid=(C, Qp // q_block, Gp // g_block),
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, g_block, D), lambda c, i, j: (c, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, g_block),
                               lambda c, i, j: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, Qp, Gp), jnp.float32),
        interpret=interpret,
    )(qp, gp)
    return out[:, :Q, :G]
