"""Pallas TPU kernel: batched grouped top-k sparsify + pack (wire codec).

The comm subsystem's sparsify stage keeps, within every group of ``group``
contiguous elements, the ``kg`` largest-magnitude entries (exact, ties by
lowest index) and ships them as (values, packed int32 indices) in
magnitude-rank order. The group-local budget is what makes top-k
hardware-friendly: selection is an O(group^2) counting compare per group
(a (G, G) broadcast on the VPU), and packing is a one-hot reduction into a
REGULAR output layout (group b's survivors occupy slots [b*kg, (b+1)*kg))
— no global sort, no scatter, no cross-tile communication, so the grid is
embarrassingly parallel over (client, tile). Global exact top-k lives in
the host codec (``comm.codec.topk_select_host``) where numpy's introselect
is the right tool; on the wire the two formats carry identical byte counts
at the same keep fraction.

Semantics are bit-identical to ``ref.batched_topk_pack_ref`` and to the
numpy host codec (same counting formulas), which the comm-round bench
asserts. The unpack kernel mirrors the pack (one-hot expansion per group).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.compat import default_interpret

GROUP = 8
P_BLOCK = 2048


def _block_for(group: int, p: int, cap: int = P_BLOCK) -> int:
    """Largest group-multiple tile <= cap (at least one group)."""
    return group * max(1, min(cap, p) // group)


def _pack_kernel(x_ref, v_ref, i_ref, *, group: int, kg: int):
    t = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)                     # (1, pb)
    pb = x.shape[1]
    nb = pb // group
    xg = x.reshape(nb, group)
    a = jnp.abs(xg)
    ii = jax.lax.broadcasted_iota(jnp.int32, (group, group), 0)  # i
    jj = jax.lax.broadcasted_iota(jnp.int32, (group, group), 1)  # j
    ai = a[:, :, None]
    aj = a[:, None, :]
    beats = jnp.logical_or(aj > ai, jnp.logical_and(aj == ai, jj < ii))
    rank = jnp.sum(beats.astype(jnp.int32), axis=-1)       # (nb, G)
    onehot = (rank[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (nb, group, kg), 2))
    vals = jnp.sum(xg[..., None] * onehot.astype(jnp.float32), axis=1)
    base = (t * pb
            + jax.lax.broadcasted_iota(jnp.int32, (nb, group), 0) * group
            + jax.lax.broadcasted_iota(jnp.int32, (nb, group), 1))
    idx = jnp.sum(base[..., None] * onehot.astype(jnp.int32), axis=1)
    v_ref[...] = vals.reshape(1, nb * kg)
    i_ref[...] = idx.reshape(1, nb * kg)


def batched_topk_pack(x, *, group: int = GROUP, kg: int,
                      p_block: int = P_BLOCK,
                      interpret: Optional[bool] = None):
    """(C, P) -> (values (C, nb*kg) fp32, indices (C, nb*kg) int32),
    nb = ceil(P/group): every group keeps its kg largest magnitudes."""
    if interpret is None:
        interpret = default_interpret()
    C, P = x.shape
    pb = _block_for(group, P, p_block)
    Pp = (P + pb - 1) // pb * pb
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, Pp - P)))
    nb_total = Pp // group
    ob = (pb // group) * kg                                # out tile width

    vals, idx = pl.pallas_call(
        functools.partial(_pack_kernel, group=group, kg=kg),
        grid=(C, Pp // pb),
        in_specs=[pl.BlockSpec((1, pb), lambda c, t: (c, t))],
        out_specs=[
            pl.BlockSpec((1, ob), lambda c, t: (c, t)),
            pl.BlockSpec((1, ob), lambda c, t: (c, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, nb_total * kg), jnp.float32),
            jax.ShapeDtypeStruct((C, nb_total * kg), jnp.int32),
        ],
        interpret=interpret,
    )(xp)
    K = ((P + group - 1) // group) * kg
    return vals[:, :K], idx[:, :K]


def _bitpack_kernel(i_ref, o_ref, *, group: int, kg: int, k: int,
                    bits: int):
    ix = i_ref[...]                                        # (1, kp) int32
    kp = ix.shape[1]
    kb = kp // 8
    s = jax.lax.broadcasted_iota(jnp.int32, (1, kp), 1)
    # local in-group index per pack slot; padding slots (s >= k) pack as 0
    li = jnp.where(s < k, ix - (s // kg) * group, 0)
    lib = li.reshape(kb, 8)
    lane = jax.lax.broadcasted_iota(jnp.int32, (kb, 8), 1)
    weight = jnp.left_shift(jnp.ones((kb, 8), jnp.int32), lane)
    planes = [jnp.sum(((lib >> j) & 1) * weight, axis=1)   # (kb,) per plane
              for j in range(bits)]
    o_ref[...] = jnp.concatenate(planes).reshape(1, bits * kb) \
                    .astype(jnp.uint8)


def batched_idx_bitpack(x, *, group: int = GROUP, kg: int,
                        interpret: Optional[bool] = None):
    """(C, K) int32 grouped-pack indices -> (C, bits*ceil(K/8)) uint8
    bitplanes, bits = ceil(log2(group)): only the 3-bit (at group=8) local
    index per slot crosses the wire; the absolute index is slot-position
    arithmetic. Bitplane-major layout (plane j = bit j of every slot, 8
    slots per byte) keeps the kernel pure shift/mask/reduce — no gather.
    Bit-identical to ``ref.batched_idx_bitpack_ref``."""
    if interpret is None:
        interpret = default_interpret()
    C, K = x.shape
    bits = (group - 1).bit_length()
    kb = (K + 7) // 8
    kp = kb * 8
    xp = jnp.pad(x, ((0, 0), (0, kp - K)))
    return pl.pallas_call(
        functools.partial(_bitpack_kernel, group=group, kg=kg, k=K,
                          bits=bits),
        grid=(C,),
        in_specs=[pl.BlockSpec((1, kp), lambda c: (c, 0))],
        out_specs=pl.BlockSpec((1, bits * kb), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, bits * kb), jnp.uint8),
        interpret=interpret,
    )(xp)


def _bitunpack_kernel(p_ref, o_ref, *, group: int, kg: int, bits: int):
    pk = p_ref[...].astype(jnp.int32)                      # (1, bits*kb)
    kb = pk.shape[1] // bits
    b = pk.reshape(bits, kb)
    lane = jax.lax.broadcasted_iota(jnp.int32, (bits, kb, 8), 2)
    flat = ((b[..., None] >> lane) & 1).reshape(bits, kb * 8)
    li = jnp.zeros((1, kb * 8), jnp.int32)
    for j in range(bits):
        li = li + (flat[j].reshape(1, kb * 8) << j)
    s = jax.lax.broadcasted_iota(jnp.int32, (1, kb * 8), 1)
    o_ref[...] = (s // kg) * group + li


def batched_idx_bitunpack(packed, *, k: int, group: int = GROUP, kg: int,
                          interpret: Optional[bool] = None):
    """Inverse of ``batched_idx_bitpack``: uint8 bitplanes -> (C, k) int32
    absolute indices ((slot // kg) * group + local index)."""
    if interpret is None:
        interpret = default_interpret()
    C = packed.shape[0]
    bits = (group - 1).bit_length()
    kb = packed.shape[1] // bits
    out = pl.pallas_call(
        functools.partial(_bitunpack_kernel, group=group, kg=kg, bits=bits),
        grid=(C,),
        in_specs=[pl.BlockSpec((1, bits * kb), lambda c: (c, 0))],
        out_specs=pl.BlockSpec((1, kb * 8), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, kb * 8), jnp.int32),
        interpret=interpret,
    )(packed)
    return out[:, :k]


def _unpack_kernel(v_ref, i_ref, o_ref, *, group: int, kg: int):
    t = pl.program_id(1)
    v = v_ref[...].astype(jnp.float32)                     # (1, ob)
    ix = i_ref[...]                                        # (1, ob)
    pb = o_ref.shape[1]
    nb = pb // group
    vb = v.reshape(nb, kg)
    ib = ix.reshape(nb, kg)
    base = (t * pb
            + jax.lax.broadcasted_iota(jnp.int32, (nb, kg), 0) * group)
    li = ib - base                                         # local 0..G-1
    onehot = (li[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (nb, kg, group), 2))
    dense = jnp.sum(vb[..., None] * onehot.astype(jnp.float32), axis=1)
    o_ref[...] = dense.reshape(1, pb)


def batched_topk_unpack(vals, idx, *, p: int, group: int = GROUP, kg: int,
                        p_block: int = P_BLOCK,
                        interpret: Optional[bool] = None):
    """Inverse of ``batched_topk_pack``: one-hot expand (C, nb*kg) values
    back into dense (C, p) fp32 rows (dropped entries zero)."""
    if interpret is None:
        interpret = default_interpret()
    C, K = vals.shape
    pb = _block_for(group, p, p_block)
    Pp = (p + pb - 1) // pb * pb
    ob = (pb // group) * kg
    Kp = (Pp // group) * kg
    vp = jnp.pad(vals.astype(jnp.float32), ((0, 0), (0, Kp - K)))
    # padded slots carry value 0 and index -1: -1 can never equal a local
    # in-group index (0..group-1), so they contribute nothing even in the
    # first tile (index 0 would alias group 0's first element there)
    ip = jnp.pad(idx, ((0, 0), (0, Kp - K)), constant_values=-1)

    out = pl.pallas_call(
        functools.partial(_unpack_kernel, group=group, kg=kg),
        grid=(C, Pp // pb),
        in_specs=[
            pl.BlockSpec((1, ob), lambda c, t: (c, t)),
            pl.BlockSpec((1, ob), lambda c, t: (c, t)),
        ],
        out_specs=pl.BlockSpec((1, pb), lambda c, t: (c, t)),
        out_shape=jax.ShapeDtypeStruct((C, Pp), jnp.float32),
        interpret=interpret,
    )(vp, ip)
    return out[:, :p]
