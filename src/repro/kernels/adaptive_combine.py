"""Pallas TPU kernel: fused FedSTIL adaptive combine (paper Eq. 2)

    theta = B ⊙ alpha + A

Applied to every adaptive tensor at every training step on every client —
a fused multiply-add streaming kernel (one pass over HBM instead of two for
the unfused mul+add). Arrays are flattened and tiled (8 x 1024) in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
COLS = 1024
TILE = ROWS * COLS


def _combine_kernel(b_ref, al_ref, a_ref, o_ref):
    o_ref[...] = (b_ref[...].astype(jnp.float32)
                  * al_ref[...].astype(jnp.float32)
                  + a_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def adaptive_combine(base, alpha, a, *, interpret: bool = True):
    """Elementwise B*alpha + A for a single array of any shape."""
    shape = base.shape
    n = base.size
    npad = (n + TILE - 1) // TILE * TILE
    def prep(x):
        return jnp.pad(jnp.ravel(x), (0, npad - n)).reshape(-1, COLS)
    bf, alf, af = prep(base), prep(alpha), prep(a)
    rows = bf.shape[0]

    out = pl.pallas_call(
        _combine_kernel,
        grid=(rows // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, COLS), base.dtype),
        interpret=interpret,
    )(bf, alf, af)
    return jnp.ravel(out)[:n].reshape(shape)


def adaptive_combine_tree(base_tree, alpha_tree, a_tree, *, interpret=True):
    """Leaf-wise Eq. 2 over a full adaptive pytree."""
    return jax.tree.map(
        lambda b, al, a: adaptive_combine(b, al, a, interpret=interpret),
        base_tree, alpha_tree, a_tree)
