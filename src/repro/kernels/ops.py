"""Public jit'd wrappers for the Pallas kernels.

``backend`` selects the implementation:
  * "ref"       — pure-jnp oracle (default on CPU / in the dry-run HLO)
  * "pallas"    — compiled Pallas TPU kernel (production)
  * "interpret" — Pallas kernel body interpreted on CPU (correctness tests)
  * "auto"/None — "pallas" on TPU, "ref" everywhere else
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.analysis.registry import register_program
from repro.kernels import ref as REF
from repro.kernels.adaptive_combine import adaptive_combine as _combine
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int8_dist import batched_int8_pairwise_dist as _bi8dist
from repro.kernels.ivf import batched_cluster_dist as _bcdist
from repro.kernels.ivf import batched_ivf_shortlist_scores as _bivfshort
from repro.kernels.kl_similarity import kl_similarity as _kl
from repro.kernels.pairwise_dist import batched_pairwise_dist as _bpdist
from repro.kernels.pairwise_dist import pairwise_dist as _pdist
from repro.kernels.quantize import batched_dequantize as _bdequant
from repro.kernels.quantize import batched_quantize as _bquant
from repro.kernels.relevance_aggregate import relevance_aggregate as _agg
from repro.kernels.relevance_aggregate import \
    fused_relevance_aggregate as _fused_agg
from repro.kernels.topk_pack import batched_idx_bitpack as _bidxpack
from repro.kernels.topk_pack import batched_idx_bitunpack as _bidxunpack
from repro.kernels.topk_pack import batched_topk_pack as _btopk
from repro.kernels.topk_pack import batched_topk_unpack as _buntopk

DEFAULT_BACKEND = "auto"

# ---- static-analysis registration (repro.analysis) -------------------------
# Every dispatcher registers with bench-scale abstract shapes (C=100 clients,
# P=4096 payload entries — where the BENCH_*.json sweeps top out) and
# backend="ref" so the traced program is pallas_call-free. Tracing is lazy;
# the decorator only records metadata.
_S = jax.ShapeDtypeStruct
_AC, _AP = 100, 4096                      # analysis-time client / payload dims


def _f32(*shape):
    return _S(shape, jnp.float32)


def _dispatch(backend):
    b = backend or DEFAULT_BACKEND
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "ref"
    if b not in ("ref", "pallas", "interpret"):
        raise ValueError(f"unknown kernel backend {b!r}")
    return b


@register_program(
    "kernels.flash_attention",
    abstract_args=lambda: ((_f32(2, 4, 128, 64),) * 3,
                           {"causal": True, "backend": "ref"}),
    oracle="repro.kernels.ref.flash_attention_ref", budget_bytes=64 << 20)
@functools.partial(jax.jit, static_argnames=("causal", "backend"))
def flash_attention(q, k, v, *, causal: bool = True, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.flash_attention_ref(q, k, v, causal=causal)
    return _flash(q, k, v, causal=causal, interpret=(b == "interpret"))


@register_program(
    "kernels.pairwise_dist",
    abstract_args=lambda: ((_f32(128, 64), _f32(256, 64)),
                           {"backend": "ref"}),
    oracle="repro.kernels.ref.pairwise_dist_ref", budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def pairwise_dist(q, g, *, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.pairwise_dist_ref(q, g)
    return _pdist(q, g, interpret=(b == "interpret"))


@register_program(
    "kernels.batched_pairwise_dist",
    abstract_args=lambda: ((_f32(_AC, 48, 64), _f32(_AC, 96, 64)),
                           {"backend": "ref"}),
    oracle="repro.kernels.ref.batched_pairwise_dist_ref",
    budget_bytes=64 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def batched_pairwise_dist(q, g, *, backend: str = None):
    """(C, Q, D) x (C, G, D) -> (C, Q, G): all clients' distance matrices
    in one launch (the batched retrieval-eval hot spot)."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_pairwise_dist_ref(q, g)
    return _bpdist(q, g, interpret=(b == "interpret"))


@register_program(
    "kernels.batched_int8_pairwise_dist",
    abstract_args=lambda: ((_f32(8, 32, 64), _S((8, 4096, 64), jnp.int8),
                            _f32(8, 4096), _f32(8, 4096)),
                           {"backend": "ref"}),
    oracle="repro.kernels.ref.batched_int8_pairwise_dist_ref",
    budget_bytes=32 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def batched_int8_pairwise_dist(q, gq, gscale, gn2, *, backend: str = None):
    """(C, B, F) fp32 queries x int8 resident gallery ((C, G, F) codes +
    (C, G) scales + (C, G) dequantized squared norms) -> (C, B, G): the
    serving-path distance hot spot (see repro.serving)."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_int8_pairwise_dist_ref(q, gq, gscale, gn2)
    return _bi8dist(q, gq, gscale, gn2, interpret=(b == "interpret"))


@register_program(
    "kernels.batched_cluster_assign",
    abstract_args=lambda: ((_f32(8, 32, 64), _f32(8, 64, 64), _f32(8, 64)),
                           {"nprobe": 8, "backend": "ref"}),
    oracle="repro.kernels.ref.batched_cluster_assign_ref",
    budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("nprobe", "backend"))
def batched_cluster_assign(qf, cent, cn2, *, nprobe: int,
                           backend: str = None):
    """IVF coarse-quantizer stage: (C, B, F) fp32 queries x ((C, L, F)
    centroids + (C, L) sq-norms) -> (C, B, nprobe) int32 nearest-bucket
    ids (query x centroid distances + ``lax.top_k`` nprobe selection)."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_cluster_assign_ref(qf, cent, cn2, nprobe=nprobe)
    dc = _bcdist(qf, cent, cn2, interpret=(b == "interpret"))
    return jax.lax.top_k(-dc, nprobe)[1]


@register_program(
    "kernels.batched_ivf_shortlist",
    abstract_args=lambda: ((_f32(8, 32, 64), _S((8, 32, 8), jnp.int32),
                            _S((8, 64, 96, 64), jnp.int8),
                            _f32(8, 64, 3, 96)),
                           {"backend": "ref"}),
    oracle="repro.kernels.ref.batched_ivf_shortlist_ref",
    budget_bytes=32 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def batched_ivf_shortlist(qf, probe, bq, pack, *, backend: str = None):
    """IVF shortlist stage: score only the probed buckets of the
    bucket-major int8 image. (C, B, F) queries + (C, B, P) probe ids x
    ((C, L, K, F) int8 bucket rows, (C, L, 3, K) packed sidecar) ->
    ((C, B, P*K) partial squared distances, (C, B, P*K) row ids, -1 on
    empty slots). Rows scored per query: P*K ~ nprobe * bcap << G."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_ivf_shortlist_ref(qf, probe, bq, pack)
    C, B, P = probe.shape
    K = bq.shape[2]
    d = _bivfshort(qf, probe, bq, pack, interpret=(b == "interpret"))
    pids = jax.lax.bitcast_convert_type(pack[:, :, 2, :], jnp.int32)
    ids = jnp.take_along_axis(pids, probe.reshape(C, B * P)[:, :, None],
                              axis=1).reshape(C, B, P, K)
    return d.reshape(C, B, P * K), ids.reshape(C, B, P * K)


@register_program(
    "kernels.adaptive_combine",
    abstract_args=lambda: ((_f32(_AC, _AP),) * 3, {"backend": "ref"}),
    oracle="repro.kernels.ref.adaptive_combine_ref", budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def adaptive_combine(base, alpha, a, *, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.adaptive_combine_ref(base, alpha, a)
    return _combine(base, alpha, a, interpret=(b == "interpret"))


@register_program(
    "kernels.relevance_aggregate",
    abstract_args=lambda: ((_f32(_AC, _AC), _f32(_AC, _AP)),
                           {"backend": "ref"}),
    oracle="repro.kernels.ref.relevance_aggregate_ref",
    budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def relevance_aggregate(w, thetas, *, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.relevance_aggregate_ref(w, thetas)
    return _agg(w, thetas, interpret=(b == "interpret"))


@register_program(
    "kernels.fused_relevance_aggregate",
    abstract_args=lambda: ((_f32(_AC, _AC), _f32(_AC, _AP)),
                           {"backend": "ref"}),
    oracle="repro.kernels.ref.fused_relevance_aggregate_ref",
    budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def fused_relevance_aggregate(w, thetas, *, backend: str = None):
    """Diag-mask + row-normalize + W @ Θ in one program -> (B, Wn)."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.fused_relevance_aggregate_ref(w, thetas)
    return _fused_agg(w, thetas, interpret=(b == "interpret"))


@register_program(
    "kernels.batched_quantize",
    abstract_args=lambda: ((_f32(_AC, _AP),),
                           {"chunk": 256, "backend": "ref"}),
    oracle="repro.kernels.ref.batched_quantize_ref", budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def batched_quantize(x, *, chunk: int = 256, backend: str = None):
    """Wire-codec quantize stage: (C, P) fp32 -> ((C, P) int8, per-chunk
    scales) for all C clients' payloads in one launch."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_quantize_ref(x, chunk=chunk)
    return _bquant(x, chunk=chunk, interpret=(b == "interpret"))


@register_program(
    "kernels.batched_dequantize",
    abstract_args=lambda: ((_S((_AC, _AP), jnp.int8),
                            _f32(_AC, _AP // 256)),
                           {"chunk": 256, "backend": "ref"}),
    oracle="repro.kernels.ref.batched_dequantize_ref",
    budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def batched_dequantize(q, scales, *, chunk: int = 256, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_dequantize_ref(q, scales, chunk=chunk)
    return _bdequant(q, scales, chunk=chunk, interpret=(b == "interpret"))


@register_program(
    "kernels.batched_topk_pack",
    abstract_args=lambda: ((_f32(_AC, _AP),),
                           {"group": 8, "kg": 2, "backend": "ref"}),
    oracle="repro.kernels.ref.batched_topk_pack_ref", budget_bytes=32 << 20)
@functools.partial(jax.jit, static_argnames=("group", "kg", "backend"))
def batched_topk_pack(x, *, group: int = 8, kg: int, backend: str = None):
    """Wire-codec sparsify stage: (C, P) -> (values (C, ceil(P/group)*kg),
    packed int32 indices); exact top-kg magnitudes per group of ``group``
    contiguous elements, deterministic ties (lowest index)."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_topk_pack_ref(x, group=group, kg=kg)
    return _btopk(x, group=group, kg=kg, interpret=(b == "interpret"))


@register_program(
    "kernels.batched_topk_unpack",
    abstract_args=lambda: ((_f32(_AC, _AP // 8 * 2),
                            _S((_AC, _AP // 8 * 2), jnp.int32)),
                           {"p": _AP, "group": 8, "kg": 2,
                            "backend": "ref"}),
    oracle="repro.kernels.ref.batched_topk_unpack_ref",
    budget_bytes=32 << 20)
@functools.partial(jax.jit, static_argnames=("p", "group", "kg", "backend"))
def batched_topk_unpack(vals, idx, *, p: int, group: int = 8, kg: int,
                        backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_topk_unpack_ref(vals, idx, p=p, group=group, kg=kg)
    return _buntopk(vals, idx, p=p, group=group, kg=kg,
                    interpret=(b == "interpret"))


@register_program(
    "kernels.batched_idx_bitpack",
    abstract_args=lambda: ((_S((_AC, _AP // 8 * 2), jnp.int32),),
                           {"group": 8, "kg": 2, "backend": "ref"}),
    oracle="repro.kernels.ref.batched_idx_bitpack_ref",
    budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("group", "kg", "backend"))
def batched_idx_bitpack(idx, *, group: int = 8, kg: int, backend: str = None):
    """Wire-codec index compression: (C, K) int32 grouped-pack indices ->
    (C, bits*ceil(K/8)) uint8 bitplanes (bits = ceil(log2(group)), 3 at
    group=8 — only the local in-group index ships; absolute indices are
    slot arithmetic on the receiver)."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_idx_bitpack_ref(idx, group=group, kg=kg)
    return _bidxpack(idx, group=group, kg=kg, interpret=(b == "interpret"))


@register_program(
    "kernels.batched_idx_bitunpack",
    abstract_args=lambda: ((_S((_AC, 3 * (_AP // 8 * 2 // 8)), jnp.uint8),),
                           {"k": _AP // 8 * 2, "group": 8, "kg": 2,
                            "backend": "ref"}),
    oracle="repro.kernels.ref.batched_idx_bitunpack_ref",
    budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("k", "group", "kg", "backend"))
def batched_idx_bitunpack(packed, *, k: int, group: int = 8, kg: int,
                          backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_idx_bitunpack_ref(packed, k=k, group=group, kg=kg)
    return _bidxunpack(packed, k=k, group=group, kg=kg,
                       interpret=(b == "interpret"))


@register_program(
    "kernels.kl_similarity",
    abstract_args=lambda: ((_f32(64, 128), _f32(48, 128)),
                           {"backend": "ref"}),
    oracle="repro.kernels.ref.kl_similarity_ref", budget_bytes=16 << 20)
@functools.partial(jax.jit, static_argnames=("backend",))
def kl_similarity(a, b_, *, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.kl_similarity_ref(a, b_)
    return _kl(a, b_, interpret=(b == "interpret"))
