"""Public jit'd wrappers for the Pallas kernels.

``backend`` selects the implementation:
  * "ref"       — pure-jnp oracle (default on CPU / in the dry-run HLO)
  * "pallas"    — compiled Pallas TPU kernel (production)
  * "interpret" — Pallas kernel body interpreted on CPU (correctness tests)
  * "auto"/None — "pallas" on TPU, "ref" everywhere else
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.adaptive_combine import adaptive_combine as _combine
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.kl_similarity import kl_similarity as _kl
from repro.kernels.pairwise_dist import batched_pairwise_dist as _bpdist
from repro.kernels.pairwise_dist import pairwise_dist as _pdist
from repro.kernels.relevance_aggregate import relevance_aggregate as _agg
from repro.kernels.relevance_aggregate import \
    fused_relevance_aggregate as _fused_agg

DEFAULT_BACKEND = "auto"


def _dispatch(backend):
    b = backend or DEFAULT_BACKEND
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "ref"
    if b not in ("ref", "pallas", "interpret"):
        raise ValueError(f"unknown kernel backend {b!r}")
    return b


@functools.partial(jax.jit, static_argnames=("causal", "backend"))
def flash_attention(q, k, v, *, causal: bool = True, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.flash_attention_ref(q, k, v, causal=causal)
    return _flash(q, k, v, causal=causal, interpret=(b == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def pairwise_dist(q, g, *, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.pairwise_dist_ref(q, g)
    return _pdist(q, g, interpret=(b == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def batched_pairwise_dist(q, g, *, backend: str = None):
    """(C, Q, D) x (C, G, D) -> (C, Q, G): all clients' distance matrices
    in one launch (the batched retrieval-eval hot spot)."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.batched_pairwise_dist_ref(q, g)
    return _bpdist(q, g, interpret=(b == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def adaptive_combine(base, alpha, a, *, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.adaptive_combine_ref(base, alpha, a)
    return _combine(base, alpha, a, interpret=(b == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def relevance_aggregate(w, thetas, *, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.relevance_aggregate_ref(w, thetas)
    return _agg(w, thetas, interpret=(b == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def fused_relevance_aggregate(w, thetas, *, backend: str = None):
    """Diag-mask + row-normalize + W @ Θ in one program -> (B, Wn)."""
    b = _dispatch(backend)
    if b == "ref":
        return REF.fused_relevance_aggregate_ref(w, thetas)
    return _fused_agg(w, thetas, interpret=(b == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def kl_similarity(a, b_, *, backend: str = None):
    b = _dispatch(backend)
    if b == "ref":
        return REF.kl_similarity_ref(a, b_)
    return _kl(a, b_, interpret=(b == "interpret"))
