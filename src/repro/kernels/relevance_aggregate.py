"""Pallas TPU kernel: personalized server aggregation (paper Eq. 6)

    B = W @ Θ,   W: (C, C) relevance,  Θ: (C, P) stacked client params.

P is the flattened adaptive parameter count (millions); C is small (edge
clients). W stays resident in VMEM; Θ streams in (C x p_block) tiles and
every tile is one (C,C)x(C,pb) MXU matmul — the kernel is purely
bandwidth-bound, reading each client's parameters exactly once.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.compat import default_interpret

P_BLOCK = 2048


def _agg_kernel(w_ref, t_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)          # (R, C)
    t = t_ref[...].astype(jnp.float32)          # (C, pb)
    o_ref[...] = jax.lax.dot_general(
        w, t, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def relevance_aggregate(w, thetas, *, p_block: int = P_BLOCK,
                        interpret: Optional[bool] = None):
    """w: (R, C) relevance rows; thetas: (C, P) -> (R, P). R = C in the
    classic round; R < C when the server skips zero-relevance rows."""
    if interpret is None:
        interpret = default_interpret()
    R = w.shape[0]
    C, Pn = thetas.shape
    p_block = min(p_block, max(128, Pn))
    Pp = (Pn + p_block - 1) // p_block * p_block
    tp = jnp.pad(thetas, ((0, 0), (0, Pp - Pn)))

    out = pl.pallas_call(
        _agg_kernel,
        grid=(Pp // p_block,),
        in_specs=[
            pl.BlockSpec((R, C), lambda i: (0, 0)),
            pl.BlockSpec((C, p_block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((R, p_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((R, Pp), thetas.dtype),
        interpret=interpret,
    )(w, tp)
    return out[:, :Pn]


def _normalized_w(w):
    """Diagonal-masked, row-normalized relevance; all-zero rows stay zero.

    Runs inside the kernel on the full (C, C) block — C is the client
    count, tiny next to P, so recomputing it per grid step is free and
    keeps the whole Eq. 5→6 post-processing in VMEM.
    """
    C = w.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    wm = jnp.where(row == col, 0.0, w.astype(jnp.float32))
    rows = jnp.sum(wm, axis=1, keepdims=True)
    return jnp.where(rows > 0, wm / jnp.where(rows > 0, rows, 1.0), 0.0)


def _fused_kernel(w_ref, t_ref, o_ref, wn_ref):
    wn = _normalized_w(w_ref[...])                  # (C, C) fp32
    t = t_ref[...].astype(jnp.float32)              # (C, pb)
    o_ref[...] = jax.lax.dot_general(
        wn, t, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)
    wn_ref[...] = wn                                # idempotent per grid step


def fused_relevance_aggregate(w, thetas, *, p_block: int = P_BLOCK,
                              interpret: Optional[bool] = None):
    """One fused device program for the server round's Eq. 5→6 tail:
    diagonal masking, row normalization (zero-row safe), and B = Wn @ Θ.

    w: (C, C) raw decayed relevance (diagonal ignored); thetas: (C, P).
    Returns (B: (C, P), Wn: (C, C) fp32 normalized relevance).
    """
    if interpret is None:
        interpret = default_interpret()
    C, Pn = thetas.shape
    p_block = min(p_block, max(128, Pn))
    Pp = (Pn + p_block - 1) // p_block * p_block
    tp = jnp.pad(thetas, ((0, 0), (0, Pp - Pn)))

    out, wn = pl.pallas_call(
        _fused_kernel,
        grid=(Pp // p_block,),
        in_specs=[
            pl.BlockSpec((C, C), lambda i: (0, 0)),
            pl.BlockSpec((C, p_block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((C, p_block), lambda i: (0, i)),
            pl.BlockSpec((C, C), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, Pp), thetas.dtype),
            jax.ShapeDtypeStruct((C, C), jnp.float32),
        ],
        interpret=interpret,
    )(w, tp)
    return out[:, :Pn], wn
