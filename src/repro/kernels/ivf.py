"""Pallas TPU kernels for the IVF shortlist serving path.

Two kernels back the approximate-query pipeline (repro/serving, phase 2):

  * cluster distances — fp32 query batches vs each client's nlist coarse
    centroids, the same |q|^2 + |c|^2 - 2 q.c tile math as pairwise_dist
    (the dispatcher runs ``lax.top_k`` on the result to pick nprobe
    buckets; top-k is not a kernel).
  * shortlist scores — for every (client, query, probe) the kernel loads
    ONE bucket of the bucket-major int8 image plus its packed fp32
    sidecar, dequantizes in VMEM and fp32-accumulates exactly like
    int8_dist.py. Bucket selection is data dependent, so the probe ids
    ride in as a scalar-prefetch operand and the BlockSpec index maps
    read them: grid step (c, b, j) maps the bucket operand to block
    (c, probe[c, b, j]) — the gather IS the block indexing, no in-kernel
    dynamic slicing.

Bucket-major layout (built at index refresh, see serving/index.py):

    bq    (C, nlist, bcap, F) int8   bucket rows (empty slots zeroed)
    pack  (C, nlist, 3, bcap) f32    [row scale; dequant |g|^2; row id
                                      bitcast int32->f32]

The sidecar is packed into one array so a probe costs a single
contiguous block load instead of three (measured ~20% off the CPU
shortlist launch; ids are bitcast back to int32 by the dispatcher).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import default_interpret

B_BLOCK = 64
L_BLOCK = 128


def _cdist_kernel(q_ref, c_ref, n2_ref, o_ref):
    q = q_ref[0]                                # (bb, F) fp32
    cent = c_ref[0]                             # (lb, F) fp32 centroids
    n2 = n2_ref[0]                              # (lb,) |centroid|^2
    qq = jnp.sum(q * q, -1, keepdims=True)      # (bb, 1)
    dot = jax.lax.dot_general(q, cent, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[0] = qq + n2[None, :] - 2.0 * dot


def batched_cluster_dist(qf, cent, cn2, *, b_block: int = B_BLOCK,
                         l_block: int = L_BLOCK,
                         interpret: Optional[bool] = None):
    """(C, B, F) fp32 queries x ((C, L, F) centroids, (C, L) sq-norms)
    -> (C, B, L) squared distances. B, L padded to block multiples."""
    if interpret is None:
        interpret = default_interpret()
    C, B, F = qf.shape
    L = cent.shape[1]
    b_block = min(b_block, max(8, B))
    l_block = min(l_block, max(8, L))
    Bp = (B + b_block - 1) // b_block * b_block
    Lp = (L + l_block - 1) // l_block * l_block
    qp = jnp.pad(qf, ((0, 0), (0, Bp - B), (0, 0)))
    cp = jnp.pad(cent, ((0, 0), (0, Lp - L), (0, 0)))
    np_ = jnp.pad(cn2, ((0, 0), (0, Lp - L)))

    out = pl.pallas_call(
        _cdist_kernel,
        grid=(C, Bp // b_block, Lp // l_block),
        in_specs=[
            pl.BlockSpec((1, b_block, F), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, l_block, F), lambda c, i, j: (c, j, 0)),
            pl.BlockSpec((1, l_block), lambda c, i, j: (c, j)),
        ],
        out_specs=pl.BlockSpec((1, b_block, l_block),
                               lambda c, i, j: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, Bp, Lp), jnp.float32),
        interpret=interpret,
    )(qp, cp, np_)
    return out[:, :B, :L]


def _shortlist_kernel(probe_ref, q_ref, bq_ref, pk_ref, o_ref):
    del probe_ref                               # consumed by the index maps
    q = q_ref[0, 0].reshape(1, -1)              # (1, F)
    blk = bq_ref[0, 0].astype(jnp.float32)      # (bcap, F) int8 -> f32 VMEM
    s = pk_ref[0, 0, 0]                         # (bcap,) per-row scales
    n2 = pk_ref[0, 0, 1]                        # (bcap,) dequant |g|^2
    dot = jax.lax.dot_general(blk, q, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[0, 0, 0] = n2 - 2.0 * (dot[:, 0] * s)


def batched_ivf_shortlist_scores(qf, probe, bq, pack, *,
                                 interpret: Optional[bool] = None):
    """(C, B, F) queries + (C, B, P) probe bucket ids against the
    bucket-major image -> (C, B, P, bcap) partial squared distances
    (|g|^2 - 2 q.g; the caller adds |q|^2 and masks empty slots).

    One grid step per (client, query, probe); the probe ids are a
    scalar-prefetch operand so the bucket/sidecar BlockSpecs can index
    blocks by ``probe[c, b, j]`` directly.
    """
    if interpret is None:
        interpret = default_interpret()
    C, B, F = qf.shape
    P = probe.shape[-1]
    bcap = bq.shape[2]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C, B, P),
        in_specs=[
            pl.BlockSpec((1, 1, F), lambda c, b, j, probe: (c, b, 0)),
            pl.BlockSpec((1, 1, bcap, F),
                         lambda c, b, j, probe: (c, probe[c, b, j], 0, 0)),
            pl.BlockSpec((1, 1, 3, bcap),
                         lambda c, b, j, probe: (c, probe[c, b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bcap),
                               lambda c, b, j, probe: (c, b, j, 0)),
    )
    return pl.pallas_call(
        _shortlist_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, B, P, bcap), jnp.float32),
        interpret=interpret,
    )(probe, qf, bq, pack)
