"""Pallas TPU flash-attention (forward) — the extraction-layer hot spot.

Tiling: grid over (batch*heads, q-blocks); each program streams KV blocks
through VMEM with an online-softmax accumulator held in fp32 scratch.
Block shapes are MXU-aligned (q_block x head_dim, kv_block x head_dim with
head_dim a multiple of 128 where the config allows; the lane dim is the
head_dim so 64-wide heads still map cleanly onto the 8x128 VREG tiles).

Validated against ref.flash_attention_ref in interpret mode on CPU
(tests/test_kernels.py sweeps shapes and dtypes); on TPU, pass
interpret=False for the compiled kernel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_Q_BLOCK = 128
DEFAULT_KV_BLOCK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block, causal, scale,
                 q_block, seq_k):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale            # (q_block, hd)
    hd = q.shape[-1]
    n_kv = seq_k // kv_block

    def body(i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(i * kv_block, kv_block), slice(None))
                    ).astype(jnp.float32)                 # (kv_block, hd)
        v = pl.load(v_ref, (pl.dslice(i * kv_block, kv_block), slice(None))
                    ).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * q_block + lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = i * kv_block + lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, -1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q.shape[0],), jnp.float32)
    a0 = jnp.zeros((q.shape[0], hd), jnp.float32)
    # causal: kv blocks past the diagonal contribute nothing — skip them
    if causal:
        hi = (qi + 1) * q_block
        n_live = (hi + kv_block - 1) // kv_block
        n_iter = jnp.minimum(n_live, n_kv)
    else:
        n_iter = n_kv
    m, l, acc = lax.fori_loop(0, n_iter, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_block: int = DEFAULT_Q_BLOCK,
                    kv_block: int = DEFAULT_KV_BLOCK, interpret: bool = True):
    """q: (B,H,Sq,hd); k,v: (B,H,Sk,hd). Sq % q_block == Sk % kv_block == 0."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * H, Sk, hd)
    vf = v.reshape(B * H, Sk, hd)

    kernel = functools.partial(_attn_kernel, kv_block=kv_block, causal=causal,
                               scale=scale, q_block=q_block, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // q_block),
        in_specs=[
            pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd)
