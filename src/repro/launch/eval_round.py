"""Batched retrieval evaluation sharded over client rows (C ≫ 1000 path).

The device-resident eval program (``federated.base.stacked_eval_program``:
vmapped feature heads → all distance matrices → mAP/CMC on device) is
embarrassingly parallel over clients: every input carries a leading C dim
and no stage contracts it. ``sharded_eval_round`` therefore just jits the
"ref"-backend program (pallas_call-free, so the lowering compiles on any
mesh backend) with ``sharding.specs.stacked_eval_specs`` shardings — GSPMD
places one block of clients per device along the client axis and emits no
cross-client collectives.

Run a CPU demo:   PYTHONPATH=src python -m repro.launch.eval_round --demo
"""
import os as _os
if __name__ == "__main__":
    _os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import set_mesh
from repro.federated.base import stacked_eval_program
from repro.sharding.specs import stacked_eval_specs, stacked_eval_theta_specs


# jitted wrappers cached per (mesh, layout): one compile per simulation,
# not one per eval round
_JIT_CACHE = {}


def sharded_eval_round(theta, qp, qids, task_mask, gp, gids, gmask, mesh, *,
                       client_axis: str = "data", ranks=(1, 3, 5)):
    """One eval round for all C clients, client rows sharded over
    ``client_axis``. Inputs/outputs as ``stacked_eval_program``; returns
    the {"mAP": (C, T), ...} metrics dict (sharded over client rows)."""
    from jax.sharding import NamedSharding

    leaves, treedef = jax.tree.flatten(theta)
    key = (mesh, client_axis, tuple(ranks), treedef,
           tuple(l.ndim for l in leaves))
    if key not in _JIT_CACHE:
        sp = stacked_eval_specs(client_axis=client_axis)
        th_sp = stacked_eval_theta_specs(theta, client_axis=client_axis)

        def ns(s):
            return NamedSharding(mesh, s)

        out_sh = {"mAP": ns(sp["metrics"])}
        for k in ranks:
            out_sh[f"R{k}"] = ns(sp["metrics"])
        _JIT_CACHE[key] = jax.jit(
            functools.partial(stacked_eval_program, ranks=tuple(ranks),
                              kernel_backend="ref"),
            in_shardings=(jax.tree.map(ns, th_sp), ns(sp["qf"]),
                          ns(sp["qids"]), ns(sp["task_mask"]), ns(sp["gf"]),
                          ns(sp["gids"]), ns(sp["gmask"])),
            out_shardings=out_sh)
    with set_mesh(mesh):
        return _JIT_CACHE[key](theta, qp, qids, task_mask, gp, gids, gmask)


def _demo():
    """8 host devices, C=8 clients sharded over data×4: the mesh-sharded
    eval round matches the single-device kernel-path program."""
    from repro.core import edge_model as EM
    from repro.core.edge_model import EdgeModelConfig

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    C, T, Q, G = 8, 3, 16, 96
    cfg = EdgeModelConfig()
    rng = np.random.default_rng(0)
    theta = jax.vmap(lambda k: EM.init_adaptive_layers(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), C))
    qp = jnp.asarray(rng.standard_normal((C, T, Q, cfg.proto_dim)), jnp.float32)
    qids = jnp.asarray(rng.integers(0, 30, (C, T, Q)), jnp.int32)
    task_mask = jnp.asarray(np.broadcast_to(
        (np.arange(T) < 2).astype(np.float32), (C, T)))
    gp = jnp.asarray(rng.standard_normal((C, G, cfg.proto_dim)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, 30, (C, G)), jnp.int32)
    gmask = jnp.asarray((rng.random((C, G)) < 0.9).astype(np.float32))

    out = sharded_eval_round(theta, qp, qids, task_mask, gp, gids, gmask,
                             mesh)
    ref = stacked_eval_program(theta, qp, qids, task_mask, gp, gids, gmask,
                               kernel_backend="interpret")
    for k in out:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=1e-5)
    print(f"sharded eval round (C={C} over data×{mesh.shape['data']}) == "
          f"kernel path; mean mAP={float(jnp.mean(out['mAP'])):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    ap.parse_args()
    _demo()


if __name__ == "__main__":
    main()
