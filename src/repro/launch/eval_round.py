"""Batched retrieval evaluation sharded over client rows (C ≫ 1000 path).

The device-resident eval program (``federated.base.stacked_eval_program``:
vmapped feature heads → all distance matrices → mAP/CMC on device) is
embarrassingly parallel over clients: every input carries a leading C dim
and no stage contracts it. The one sharded implementation is
``federated.base.sharded_eval_fn`` — the engine path that
``run_simulation(engine="sharded")`` uses — which jits the "ref"-backend
program (pallas_call-free, so the lowering compiles on any mesh backend)
and lets computation follow the data: inputs are placed with
``sharding.specs.stacked_eval_specs`` client-row shardings, GSPMD puts one
block of clients per device along the client axis and emits no
cross-client collectives. This CLI is just a demo/lowering harness around
that function.

Run a CPU demo:   PYTHONPATH=src python -m repro.launch.eval_round --demo
"""
import os as _os
if __name__ == "__main__":
    _os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.base import sharded_eval_fn, stacked_eval_program
from repro.sharding.specs import (named_shardings, stacked_eval_specs,
                                  stacked_eval_theta_specs)


def _demo():
    """8 host devices, C=8 clients sharded over data×4: the mesh-sharded
    eval round matches the single-device kernel-path program."""
    from repro.core import edge_model as EM
    from repro.core.edge_model import EdgeModelConfig

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    C, T, Q, G = 8, 3, 16, 96
    cfg = EdgeModelConfig()
    rng = np.random.default_rng(0)
    theta = jax.vmap(lambda k: EM.init_adaptive_layers(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), C))
    qp = jnp.asarray(rng.standard_normal((C, T, Q, cfg.proto_dim)), jnp.float32)
    qids = jnp.asarray(rng.integers(0, 30, (C, T, Q)), jnp.int32)
    task_mask = jnp.asarray(np.broadcast_to(
        (np.arange(T) < 2).astype(np.float32), (C, T)))
    gp = jnp.asarray(rng.standard_normal((C, G, cfg.proto_dim)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, 30, (C, G)), jnp.int32)
    gmask = jnp.asarray((rng.random((C, G)) < 0.9).astype(np.float32))

    # computation follows data: place client rows along the data axis, then
    # the engine's jitted eval program re-specializes SPMD on the layout
    sp = stacked_eval_specs()
    sh = named_shardings(mesh, sp)
    theta_sh = jax.device_put(
        theta, named_shardings(mesh, stacked_eval_theta_specs(theta)))
    qp, qids, task_mask, gp, gids, gmask = (
        jax.device_put(a, sh[k]) for a, k in
        ((qp, "qf"), (qids, "qids"), (task_mask, "task_mask"),
         (gp, "gf"), (gids, "gids"), (gmask, "gmask")))
    out = sharded_eval_fn(mesh, kernel_backend="ref")(
        theta_sh, qp, qids, task_mask, gp, gids, gmask)
    ref = stacked_eval_program(theta, qp, qids, task_mask, gp, gids, gmask,
                               kernel_backend="interpret")
    for k in out:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=1e-5)
    print(f"sharded eval round (C={C} over data×{mesh.shape['data']}) == "
          f"kernel path; mean mAP={float(jnp.mean(out['mAP'])):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    ap.parse_args()
    _demo()


if __name__ == "__main__":
    main()
