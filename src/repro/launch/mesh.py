"""Production mesh builders (TPU v5e).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
*before* any jax initialization; tests/benches must keep seeing 1 device.

Axis semantics (DESIGN.md §3):
  * "model": tensor/expert parallel within a pod row.
  * "data":  batch + federated-client parallel.
  * "pod":   cross-pod data/client parallel (pods = spatial regions of edge
    clients in the FedSTIL deployment story).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(tp: int = 2, dp: int = 2, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


# TPU v5e hardware constants (roofline §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
