"""Step functions lowered by the dry-run / launched on real meshes.

Everything is written for ``jax.shard_map`` over the production mesh: model
code receives local shards and emits explicit collectives via AxisCtx.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.axes import AxisCtx
from repro.common.compat import shard_map
from repro.configs.base import (
    LONG_CONTEXT_WINDOW,
    INPUT_SHAPES,
    ModelConfig,
    ShapeConfig,
)
from repro.models import lm
from repro.models import layers as MLAYERS
from repro.sharding import specs as SPECS
from repro.train import trainer as TR
from repro.train.optimizer import adam

ENC_PAD = 1536   # whisper stub frames padded 1500 -> 1536 for TP shardability


def axis_ctx(cfg: ModelConfig, multi_pod: bool) -> AxisCtx:
    return AxisCtx(tp="model", dp="data", pod="pod" if multi_pod else None,
                   fsdp=cfg.fsdp)


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_struct(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.family == "vlm":
        batch["tokens"] = _sds((B, S - cfg.n_vision_tokens), jnp.int32)
        batch["labels"] = _sds((B, S - cfg.n_vision_tokens), jnp.int32)
        batch["vision_embeds"] = _sds((B, cfg.n_vision_tokens, cfg.d_model),
                                      jnp.bfloat16)
    elif cfg.family == "encdec":
        batch["tokens"] = _sds((B, S), jnp.int32)
        batch["labels"] = _sds((B, S), jnp.int32)
        batch["frames"] = _sds((B, ENC_PAD, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
        batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def prefill_batch_struct(cfg: ModelConfig, shape: ShapeConfig):
    b = train_batch_struct(cfg, shape)
    b.pop("labels")
    return b


def decode_inputs_struct(cfg: ModelConfig, shape: ShapeConfig,
                         kv_dtype=jnp.bfloat16):
    """(cache, token, pos) structs. long_500k uses a ring-buffer cache of the
    sliding window size for attention caches (SSM states are O(1) anyway)."""
    B, S = shape.global_batch, shape.seq_len
    ring = shape.name == "long_500k" and cfg.family not in ("ssm",)
    s_cache = LONG_CONTEXT_WINDOW if ring else S
    cache = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, s_cache, enc_seq_local=ENC_PAD,
                              dtype=kv_dtype, tp=1))
    token = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return cache, token, pos


def input_specs(arch_cfg: ModelConfig, shape_name: str):
    """Public helper (see system spec): ShapeDtypeStruct stand-ins for every
    model input of (arch, input-shape)."""
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train":
        return train_batch_struct(arch_cfg, shape)
    if shape.mode == "prefill":
        return prefill_batch_struct(arch_cfg, shape)
    cache, token, pos = decode_inputs_struct(arch_cfg, shape)
    return {"cache": cache, "token": token, "pos": pos}


# ---------------------------------------------------------------------------
# step builders (already shard_map-wrapped; .lower() with global structs)
# ---------------------------------------------------------------------------


def _shmap(fn, mesh, in_specs, out_specs, check=True):
    # check_vma=True: jax tracks replication so psum transposes correctly
    # (with it off, grad-of-psum double-counts across the axis). Gradient
    # paths therefore ALWAYS run checked; the one exception is batch-
    # replicated decode of FSDP archs (no autodiff there), where gathered
    # weights make semantically-replicated outputs formally "varying".
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=check)


def abstract_train_state(cfg: ModelConfig, tp: int):
    """Abstract (never-allocated) FedSTIL train state pytrees."""
    opt = adam(lr=1e-3, weight_decay=1e-5)

    def build():
        st = TR.init_train_state(cfg, jax.random.PRNGKey(0), tp=tp, optimizer=opt)
        return (st.frozen, st.B, st.trainable, st.opt_state)
    return jax.eval_shape(build)


def build_train_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                     *, multi_pod: bool, layout: str = "tp"):
    """layout="tp": Megatron TP over the model axis (default).
    layout="dp": small-model configuration — the model axis carries BATCH
    (params replicated, zero activation collectives; only the adaptive-grad
    psum remains). §Perf hillclimb for edge-scale archs."""
    tp = mesh.shape["model"]
    dp = mesh.shape["data"]
    window = 0

    if layout == "dp":
        ax = AxisCtx(tp=None, dp="data", pod="pod" if multi_pod else None,
                     dp2="model", fsdp=False)
        tp_build = 1
    else:
        ax = axis_ctx(cfg, multi_pod)
        tp_build = tp

    frozen, B, trainable, opt_state = abstract_train_state(cfg, tp_build)
    batch = train_batch_struct(cfg, shape)

    if layout == "dp":
        rep = lambda tree: jax.tree.map(
            lambda l: P(*([None] * len(l.shape))), tree)
        eff_dp = dp * tp * (2 if multi_pod else 1)
        if shape.global_batch % eff_dp:
            raise ValueError("dp layout needs batch divisible by all axes")
        baxes = (("pod", "data", "model") if multi_pod else ("data", "model"))
        bspec = jax.tree.map(
            lambda l: P(*((baxes,) + (None,) * (len(l.shape) - 1))), batch)
        in_specs = (rep(frozen), rep(B), rep(trainable), rep(opt_state), bspec)
        out_specs = (rep(trainable), rep(opt_state),
                     {"loss": P(), "ce": P(), "moe_aux": P(), "grad_norm": P()})
    else:
        sp = functools.partial(SPECS.tree_param_specs, cfg, tp_size=tp)
        in_specs = (sp(frozen), sp(B), sp(trainable), sp(opt_state),
                    SPECS.batch_specs(cfg, batch, shape.global_batch, dp,
                                      multi_pod))
        out_specs = (sp(trainable), sp(opt_state),
                     {"loss": P(), "ce": P(), "moe_aux": P(), "grad_norm": P()})

    step = TR.make_train_step(cfg, ax=ax, window=window, tie_lambda=1e-4)
    fn = _shmap(step, mesh, in_specs, out_specs)
    args = (frozen, B, trainable, opt_state, batch)
    return jax.jit(fn), args, in_specs


def build_prefill_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                       *, multi_pod: bool):
    tp = mesh.shape["model"]
    dp = mesh.shape["data"]
    ax = axis_ctx(cfg, multi_pod)

    params = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0), tp=tp))
    batch = prefill_batch_struct(cfg, shape)

    def prefill(params, batch):
        x, _ = lm.forward(cfg, params, batch, ax)
        last = x[:, -1:, :]
        tok, _ = MLAYERS.lm_head_logits(cfg, params["head"], last, ax)
        return tok.astype(jnp.int32)

    b_axes = SPECS.batch_axes(shape.global_batch, dp, multi_pod)
    in_specs = (SPECS.tree_param_specs(cfg, params, tp_size=tp),
                SPECS.batch_specs(cfg, batch, shape.global_batch, dp, multi_pod))
    out_specs = P(b_axes, None)
    fn = _shmap(prefill, mesh, in_specs, out_specs)
    return jax.jit(fn), (params, batch), in_specs


def build_decode_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                      *, multi_pod: bool, weight_stationary: bool = False,
                      kv_dtype=jnp.bfloat16):
    tp = mesh.shape["model"]
    dp = mesh.shape["data"]
    ax = axis_ctx(cfg, multi_pod)
    if weight_stationary:
        ax = dataclasses.replace(ax, decode_ws=True)
    ring = shape.name == "long_500k" and cfg.family not in ("ssm",)
    window = LONG_CONTEXT_WINDOW if shape.name == "long_500k" else 0

    params = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0), tp=tp))
    cache, token, pos = decode_inputs_struct(cfg, shape, kv_dtype=kv_dtype)

    def serve_step(params, cache, token, pos):
        return lm.decode_step(cfg, params, cache, token, pos, ax,
                              window=window, ring=ring, enc_len=ENC_PAD)

    p_specs = SPECS.tree_param_specs(cfg, params, tp_size=tp)
    c_specs = SPECS.cache_specs(cfg, cache, shape.global_batch, dp, multi_pod)
    b_axes = SPECS.batch_axes(shape.global_batch, dp, multi_pod)
    in_specs = (p_specs, c_specs, P(b_axes, None), P())
    out_specs = (P(b_axes, None), c_specs)
    check = not ((b_axes is None and cfg.fsdp) or weight_stationary)
    fn = _shmap(serve_step, mesh, in_specs, out_specs, check=check)
    return jax.jit(fn), (params, cache, token, pos), in_specs


def build_step(cfg: ModelConfig, mesh, shape_name: str, *, multi_pod: bool):
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train":
        return build_train_step(cfg, mesh, shape, multi_pod=multi_pod)
    if shape.mode == "prefill":
        return build_prefill_step(cfg, mesh, shape, multi_pod=multi_pod)
    return build_decode_step(cfg, mesh, shape, multi_pod=multi_pod)
