"""ReID retrieval serving launcher: device-resident int8 gallery index +
continuous query batching (repro.serving). Builds a synthetic fleet,
streams queries through the batcher at peak throughput, demonstrates a
mid-stream federated-round index update, and prints QPS / p50 / p99.
(The LM-decode launcher this module used to hold is now
``repro.launch.serve_lm``.)

Usage:
  PYTHONPATH=src python -m repro.launch.serve --clients 4 --gallery 8192 \
      --queries 512 --batch 64 --mode int8

With ``--trace out.jsonl`` the run executes under a live ``repro.obs``
tracer: serve.batch / serve.index_refresh spans, bucket-exact latency
histograms and rolling QPS from a ``ServeStats`` wired into the batcher,
and IVF probe metrics when ``--mode ivf``. Inspect the sink with
``python -m repro.obs.report out.jsonl``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import edge_model as EM
from repro.obs import trace as obs
from repro.obs.metrics import ServeStats
from repro.serving import ContinuousBatcher, GalleryIndex, RetrievalEngine
from repro.serving.batcher import run_closed_loop


def _stack_thetas(keys, cfg):
    thetas = [EM.init_adaptive_layers(k, cfg) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *thetas)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--gallery", type=int, default=8192)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--mode", choices=("int8", "fp32", "ivf"), default="int8")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="coarse buckets scored per query (ivf mode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.jsonl",
                    help="write a repro.obs telemetry JSONL (spans + serve "
                         "stats); read it with python -m repro.obs.report")
    args = ap.parse_args()

    tracer = obs.Tracer(path=args.trace) if args.trace else obs.NullTracer()
    with obs.active(tracer):
        _serve(args)
    if args.trace:
        tracer.close()
        print(f"telemetry: {args.trace}  "
              f"(python -m repro.obs.report {args.trace})")


def _serve(args):
    cfg = EM.EdgeModelConfig()
    rng = np.random.default_rng(args.seed)
    C, G = args.clients, args.gallery
    protos = [rng.standard_normal((G, cfg.proto_dim), np.float32)
              for _ in range(C)]
    ids = [np.arange(G, dtype=np.int32) for _ in range(C)]
    keys = jax.random.split(jax.random.PRNGKey(args.seed), C)
    theta = _stack_thetas(keys, cfg)

    t0 = time.perf_counter()
    index = GalleryIndex(protos, ids, keep_fp32=(args.mode == "fp32"),
                         nlist="auto" if args.mode == "ivf" else 0)
    engine = RetrievalEngine(index, theta, k=args.k, mode=args.mode,
                             nprobe=args.nprobe)
    print(f"index: C={C} G={G} mode={args.mode} "
          f"resident={index.resident_bytes(args.mode) / 1e6:.1f} MB "
          f"built in {time.perf_counter() - t0:.2f}s")

    stream = [(int(rng.integers(C)),
               rng.standard_normal(cfg.proto_dim).astype(np.float32), -1)
              for _ in range(args.queries)]

    stats = ServeStats() if obs.is_active() else None
    batcher = ContinuousBatcher(engine, batch=args.batch, stats=stats)
    # warmup launch (compile) before measuring
    batcher.submit(0, stream[0][1])
    batcher.drain()

    half = len(stream) // 2
    r1 = run_closed_loop(batcher, stream[:half])
    # a federated round lands mid-stream: new heads, same prototypes —
    # one jitted refresh and the very next batch serves the new index
    keys2 = jax.random.split(jax.random.PRNGKey(args.seed + 1), C)
    tr = time.perf_counter()
    engine.update(_stack_thetas(keys2, cfg))
    refresh_ms = (time.perf_counter() - tr) * 1e3
    r2 = run_closed_loop(batcher, stream[half:])

    for tag, r in (("pre-update ", r1), ("post-update", r2)):
        print(f"{tag}: {r['n']} queries  QPS={r['qps']:.0f}  "
              f"p50={r['p50_ms']:.2f}ms  p99={r['p99_ms']:.2f}ms")
    print(f"index update (new adaptive heads, no re-extraction): "
          f"{refresh_ms:.1f} ms")
    if stats is not None:
        obs.metric("serve.stats", stats.snapshot(), mode=args.mode)


if __name__ == "__main__":
    main()
