"""LM-decode serving launcher: batched greedy decoding with the KV-cache /
SSM-state path (the same serve_step the dry-run lowers at 32k/500k scale).
The ReID retrieval service lives in ``repro.launch.serve``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_lm --arch rwkv6-1.6b \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help=">0: sliding-window ring cache (long-context mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    total = args.prompt_len + args.gen
    cache_len = args.window if args.window else total
    ring = bool(args.window)
    cache = init_cache(cfg, args.batch, cache_len,
                       enc_seq_local=cfg.enc_seq or 0, dtype=jnp.float32)

    step = jax.jit(
        lambda p, c, t, pos: decode_step(cfg, p, c, t, pos,
                                         window=args.window, ring=ring,
                                         enc_len=cfg.enc_seq or None),
        donate_argnums=(1,))

    prompt = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    tok = jnp.asarray(prompt[:, :1], jnp.int32)
    generated = []
    t0 = time.time()
    for pos in range(total - 1):
        if pos < args.prompt_len - 1:
            nxt, cache = step(params, cache, jnp.asarray(
                prompt[:, pos:pos + 1], jnp.int32), jnp.int32(pos))
        else:
            nxt, cache = step(params, cache, tok, jnp.int32(pos))
            generated.append(np.asarray(nxt))
            tok = nxt
    wall = time.time() - t0
    gen = np.concatenate(generated, 1)
    tps = args.batch * len(generated) / wall
    print(f"arch={cfg.name} batch={args.batch} generated={gen.shape[1]} tokens"
          f" window={args.window or 'full'}")
    print(f"throughput: {tps:.1f} tok/s (CPU, reduced config)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
