"""The FedSTIL parameter server as an on-mesh collective program.

At pod scale the "parameter server" is not a process — clients live along
the data axis (one edge client per data row; pods = spatial regions), their
adaptive-layer pytrees are TP-sharded along the model axis, and one
federated round (paper Algorithm 1, lines 5-9) is a single SPMD program:

  1. every client's task feature (mean prototype, Eq. 3) is all-gathered
     over the client axis (tiny: proto_dim floats per client);
  2. task similarity (Eq. 4, KL) + decayed relevance W (Eq. 5) are computed
     replicated (C x C, tiny);
  3. personalized aggregation B_i = sum_j W_ij theta_j (Eq. 6) is ONE
     ``psum_scatter`` over the client axis: client j contributes the
     outer-scaled stack W[:, j] * theta_j and receives exactly its own B_i.
     Wire bytes/client = (C-1)/C * C * |theta| ~= C * |theta| — the same as
     the WAN cost in the paper's Table II, now over ICI.

Run a CPU demo:   PYTHONPATH=src python -m repro.launch.fed_round --demo
Dry-run at scale: PYTHONPATH=src python -m repro.launch.fed_round \
                      --arch qwen3-1.7b

``--trace out.jsonl`` records a repro.obs span per action (demo /
stacked-demo / lower, device-synced wall time each); inspect with
``python -m repro.obs.report out.jsonl`` or export a Perfetto trace via
``--chrome``.
"""
import os as _os
import sys as _sys
if "--demo" in _sys.argv or "--stacked-demo" in _sys.argv:
    _os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
else:
    _os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.common.compat import set_mesh, shard_map
from repro.common.pytree import tree_flatten_concat, tree_unflatten_concat
from repro.core.fedstil import sharded_fused_aggregate
from repro.core.relevance import decayed_relevance
from repro.obs import trace as obs


def fed_round(theta_local, task_feature_local, hist_features_local, *,
              client_axis: str, forgetting_ratio: float = 0.5):
    """One FedSTIL round from inside shard_map.

    theta_local: this client's adaptive pytree (may itself be TP-sharded —
        the aggregation is leaf-wise elementwise so TP shards aggregate
        independently, no model-axis collective needed!).
    task_feature_local: (D,) this client's current task feature.
    hist_features_local: (k, D) this client's last-k task features
        (most recent last).
    Returns (B_local: same pytree = this client's personalized base,
             W_row: (C,) this client's relevance row).
    """
    me = lax.axis_index(client_axis)

    # (1) gather every client's historical task features (tiny)
    hist = lax.all_gather(hist_features_local, client_axis)      # (C, k, D)
    C, k = hist.shape[0], hist.shape[1]

    # (2) Eq. 4/5 via the shared batched primitive: decayed similarity of
    # MY current task vs THEIR histories (hist is most-recent-last, so the
    # decay vector is reversed). "ref" keeps the lowering free of
    # pallas_call so the same program compiles on any mesh backend.
    decay = forgetting_ratio ** jnp.arange(k - 1, -1, -1, jnp.float32)
    w_row = decayed_relevance(task_feature_local[None], hist, decay,
                              metric="kl", backend="ref")[0]     # (C,)
    w_row = jnp.where(jnp.arange(C) == me, 0.0, w_row)           # j != i
    w_row = w_row / jnp.maximum(jnp.sum(w_row), 1e-9)

    # full W needed so every j knows its column: gather the rows (C x C)
    W = lax.all_gather(w_row, client_axis)                       # (C, C)

    # (3) Eq. 6 as ONE reduce-scatter over the client axis:
    # my contribution to every destination i is W[i, me] * theta_me
    flat, meta = tree_flatten_concat(theta_local)
    contrib = W[:, me][:, None] * flat[None, :]                  # (C, P_loc)
    mine = lax.psum_scatter(contrib, client_axis,
                            scatter_dimension=0, tiled=False)    # (P_loc,)
    B_local = tree_unflatten_concat(mine.astype(flat.dtype), meta)
    return B_local, w_row


def fed_round_hierarchical(theta_local, task_feature_local,
                           hist_features_local, *, client_axis: str,
                           pod_axis: str, beta: float = 0.25,
                           forgetting_ratio: float = 0.5):
    """Multi-pod FedSTIL: pods = spatial regions of edge clients.

    Within-pod: full Eq. 4-6 (KL relevance over ICI). Cross-pod: a single
    pmean of the pod-level bases over DCN, mixed in with weight ``beta`` —
    distant regions share *general* knowledge while the fine-grained
    spatial-temporal relevance stays local to the region. Cross-pod traffic
    is |theta| per round instead of the flat C_total x |theta| (the same
    comm-efficiency argument the paper makes for the WAN, one level up).
    """
    B_local, w_row = fed_round(theta_local, task_feature_local,
                               hist_features_local, client_axis=client_axis,
                               forgetting_ratio=forgetting_ratio)
    B_global = jax.tree.map(lambda l: lax.pmean(l, pod_axis), B_local)
    B_mixed = jax.tree.map(lambda a, b: (1.0 - beta) * a + beta * b,
                           B_local, B_global)
    return B_mixed, w_row


# ---------------------------------------------------------------------------
# CLI: demo + production lowering
# ---------------------------------------------------------------------------


def _demo():
    """8 host devices, 4 clients x TP2: verify against the numpy server."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    C, D, Pn, k = 4, 16, 64, 3
    key = jax.random.PRNGKey(0)
    thetas = jax.random.normal(key, (C, Pn))
    feats = jax.random.normal(jax.random.PRNGKey(1), (C, D))
    hists = jax.random.normal(jax.random.PRNGKey(2), (C, k, D))

    def step(theta, feat, hist):
        # theta local: (1, P/tp) — this client's row
        th = {"w": theta[0]}
        B, w_row = fed_round(th, feat[0], hist[0], client_axis="data")
        return B["w"][None], w_row[None]

    fn = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(P("data", "model"), P("data", None), P("data", None, None)),
        out_specs=(P("data", "model"), P("data", None))))
    with set_mesh(mesh):
        B, W = fn(thetas, feats, hists)

    # reference server: the same batched code the parameter server runs
    # (core.relevance + the Pallas Eq. 6 kernel in interpret mode)
    from repro.core.relevance import normalize_rows
    from repro.kernels import ops
    decay = 0.5 ** jnp.arange(k - 1, -1, -1, jnp.float32)
    Wref = np.array(decayed_relevance(feats, hists, decay,
                                      metric="kl", backend="ref"))
    np.fill_diagonal(Wref, 0.0)
    Wref = normalize_rows(Wref)
    Bref = np.asarray(ops.relevance_aggregate(
        jnp.asarray(Wref), thetas, backend="interpret"))
    np.testing.assert_allclose(np.asarray(W), Wref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(B), Bref, rtol=1e-3, atol=1e-4)
    print("fed_round on-mesh == batched parameter server  (W, B match)")
    print("W =\n", np.round(np.asarray(W), 3))


def _lower(arch: str, multi_pod: bool):
    """Lower a production federated round: 16 clients (data axis), each
    client's adaptive layers TP-sharded over the model axis."""
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import abstract_train_state
    from repro.sharding import specs as SPECS

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    C = mesh.shape["data"] * (mesh.shape["pod"] if multi_pod else 1)
    c_axes = ("pod", "data") if multi_pod else "data"
    _, B0, trainable, _ = abstract_train_state(cfg, tp)
    D, k = 256, 6

    # per-client adaptive pytrees: leading C dim sharded over the data axis
    theta = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((C,) + l.shape, l.dtype), B0)

    def step(theta_c, feat, hist):
        th = jax.tree.map(lambda l: l[0], theta_c)   # my client's slice
        if multi_pod:
            B, w = fed_round_hierarchical(th, feat[0], hist[0],
                                          client_axis="data", pod_axis="pod")
        else:
            B, w = fed_round(th, feat[0], hist[0], client_axis="data")
        return (jax.tree.map(lambda l: l[None], B), w[None])

    base_sp = SPECS.tree_param_specs(cfg, B0, tp_size=tp)
    sp = jax.tree.map(lambda spec: P(*((c_axes,) + tuple(spec))), base_sp,
                      is_leaf=lambda x: isinstance(x, P))
    in_specs = (sp, P(c_axes, None), P(c_axes, None, None))
    out_specs = (sp, P(c_axes, None))
    fn = jax.jit(shard_map(step, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False))
    feats = jax.ShapeDtypeStruct((C, D), jnp.float32)
    hists = jax.ShapeDtypeStruct((C, k, D), jnp.float32)
    with set_mesh(mesh):
        compiled = fn.lower(theta, feats, hists).compile()
    from repro.sharding.analysis import parse_collectives
    coll = parse_collectives(compiled.as_text())
    print(f"fed_round lowered for {arch} on {'2x16x16' if multi_pod else '16x16'}")
    print(f"  adaptive payload/client: "
          f"{sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(theta))/C/1e6:.1f} MB")
    print(f"  collective bytes/device: {coll.total_bytes/1e6:.2f} MB "
          f"{coll.count_by_kind}")


def _stacked_demo():
    """8 host devices, C=64 clients sharded 4-way × P sharded 2-way: the
    engine's mesh-sharded fused aggregate (``core.fedstil``, the one
    sharded implementation) matches the single-device kernel path."""
    from repro.kernels import ops

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    C, Pn = 64, 4096
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (C, C)))
    thetas = jax.random.normal(jax.random.PRNGKey(1), (C, Pn))
    B, Wn = sharded_fused_aggregate(w, thetas, mesh)
    Bref, Wnref = ops.fused_relevance_aggregate(w, thetas, backend="ref")
    np.testing.assert_allclose(np.asarray(Wn), np.asarray(Wnref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(B), np.asarray(Bref),
                               rtol=1e-4, atol=1e-5)
    print(f"sharded fused aggregate (C={C} over data×{mesh.shape['data']}, "
          f"P={Pn} over model×{mesh.shape['model']}) == kernel path")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--stacked-demo", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--trace", default=None, metavar="OUT.jsonl",
                    help="write a repro.obs telemetry JSONL (one span per "
                         "action); read it with python -m repro.obs.report")
    args = ap.parse_args()
    tracer = obs.Tracer(path=args.trace) if args.trace else obs.NullTracer()
    try:
        with obs.active(tracer):
            if args.stacked_demo:
                with obs.span("fed_round.stacked_demo", cat="phase"):
                    _stacked_demo()
                if not (args.demo or args.arch):
                    return
            if args.demo or not args.arch:
                with obs.span("fed_round.demo", cat="phase"):
                    _demo()
            if args.arch:
                with obs.span("fed_round.lower", cat="phase", arch=args.arch):
                    _lower(args.arch, args.multi_pod)
    finally:
        tracer.close()
        if args.trace:
            print(f"telemetry: {args.trace}  "
                  f"(python -m repro.obs.report {args.trace})")


if __name__ == "__main__":
    main()
