from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import build_step, input_specs
