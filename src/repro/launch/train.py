"""Training launcher.

Two regimes:
  * CPU / reduced (default here): runs REAL steps on the reduced config —
    the e2e driver used by examples/train_e2e.py.
  * Production mesh: builds the shard_map'd train step for the full config
    (the same function the dry-run lowers) — pass --mesh single|multi on a
    real TPU slice.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --steps 100 --batch 8 --seq 64 [--reduced] [--full]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data.tokens import synthetic_lm_batch
from repro.train import init_train_state, make_full_train_step, make_train_step
from repro.train.optimizer import adam, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-model", dest="reduced", action="store_false")
    ap.add_argument("--full", action="store_true",
                    help="train ALL params (beyond-paper), not just adaptive")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt = adam(lr=args.lr, weight_decay=1e-5,
               schedule=cosine_schedule(warmup=20, total=args.steps))
    rng = np.random.default_rng(args.seed)

    def batch_extras(B, S):
        out = {}
        if cfg.family == "vlm":
            out["vision_embeds"] = jnp.asarray(
                rng.standard_normal((B, cfg.n_vision_tokens, cfg.d_model)),
                jnp.float32)
        if cfg.family == "encdec":
            out["frames"] = jnp.asarray(
                rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32)
        return out

    st = init_train_state(cfg, jax.random.PRNGKey(args.seed), optimizer=opt)
    if args.full:
        from repro.models import init_params
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = opt.init(params)
        step = jax.jit(make_full_train_step(cfg, optimizer=opt))
    else:
        trainable, opt_state = st.trainable, st.opt_state
        step = jax.jit(make_train_step(cfg, optimizer=opt, tie_lambda=1e-4))

    t0 = time.time()
    for i in range(args.steps):
        toks, labels = synthetic_lm_batch(rng, args.batch, args.seq,
                                          cfg.vocab_size)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
                 **batch_extras(args.batch, args.seq)}
        if args.full:
            params, opt_state, m = step(params, opt_state, batch)
        else:
            trainable, opt_state, m = step(st.frozen, st.B, trainable,
                                           opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"ce {float(m['ce']):.4f}  {time.time()-t0:.1f}s", flush=True)

    if args.ckpt:
        tree = params if args.full else {"trainable": trainable, "B": st.B}
        save_checkpoint(args.ckpt, tree, metadata={"arch": args.arch,
                                                   "steps": args.steps})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
