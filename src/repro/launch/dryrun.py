import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers + compiles on the production mesh, and extract the
roofline inputs (memory / FLOPs / collective bytes) from the compiled
artifact. See DESIGN.md §3-4 and EXPERIMENTS.md §Dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                     # all 40 x 2
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k --multi-pod both --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.common.compat import set_mesh
from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as STEPS
from repro.sharding import analysis as AN


def _tree_device_bytes(tree, specs, mesh) -> int:
    """Per-device bytes of abstract arrays under their PartitionSpecs."""
    sizes = dict(mesh.shape)
    total = 0
    for leaf, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
                              x, jax.sharding.PartitionSpec))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        div = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                div *= sizes[ax]
        total += (n // max(div, 1)) * leaf.dtype.itemsize
    return total


def run_combo(arch: str, shape_name: str, multi_pod: bool, verbose=True,
              layout: str = "tp", weight_stationary: bool = False,
              kv8: bool = False):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    if shape.mode == "train" and layout != "tp":
        fn, args, in_specs = STEPS.build_train_step(
            cfg, mesh, shape, multi_pod=multi_pod, layout=layout)
    elif shape.mode == "decode" and (weight_stationary or kv8):
        import jax.numpy as jnp
        fn, args, in_specs = STEPS.build_decode_step(
            cfg, mesh, shape, multi_pod=multi_pod,
            weight_stationary=weight_stationary,
            kv_dtype=jnp.int8 if kv8 else jnp.bfloat16)
    else:
        fn, args, in_specs = STEPS.build_step(cfg, mesh, shape_name,
                                              multi_pod=multi_pod)
    with set_mesh(mesh):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- memory ----
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not support it
        mem["memory_analysis_error"] = str(e)

    # analytic per-device argument bytes from the sharded input structure
    mem["args_bytes_per_device"] = _tree_device_bytes(args, in_specs, mesh)

    # ---- cost ----
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        cost["cost_analysis_error"] = str(e)

    # ---- collectives ----
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = AN.parse_collectives(hlo)

    flops_total = cost.get("flops", 0.0)
    # XLA reports whole-program (per-partition) flops for SPMD: treat as
    # per-device; see EXPERIMENTS.md §Dry-run notes.
    hbm_bytes = cost.get("bytes accessed", 0.0)
    roof = AN.Roofline(
        flops_per_device=flops_total,
        hbm_bytes_per_device=hbm_bytes,
        collective_bytes_per_device=float(coll.total_bytes),
        n_devices=n_dev,
        model_flops=AN.analytic_model_flops(cfg, shape),
    )

    rec = {
        "arch": arch,
        "shape": shape_name,
        "layout": layout,
        "weight_stationary": weight_stationary,
        "kv8": kv8,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": cost,
        "collectives": {"bytes": coll.bytes_by_kind,
                        "count": coll.count_by_kind,
                        "total_bytes": coll.total_bytes},
        "roofline": roof.as_dict(),
    }
    if verbose:
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "lower_s", "compile_s")}),
              flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  cost_analysis: flops={flops_total:.3e} "
              f"bytes={hbm_bytes:.3e} coll={coll.total_bytes:.3e}", flush=True)
        print(f"  roofline: t_comp={roof.t_compute:.4f}s "
              f"t_mem={roof.t_memory:.4f}s t_coll={roof.t_collective:.4f}s "
              f"bottleneck={roof.bottleneck} "
              f"useful={roof.useful_flops_ratio:.3f}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp"],
                    help="dp: model axis carries batch (small-model hillclimb)")
    ap.add_argument("--ws", action="store_true",
                    help="weight-stationary decode (FSDP hillclimb)")
    ap.add_argument("--kv8", action="store_true",
                    help="int8 KV cache (decode hillclimb iteration 3)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"skip {tag} (exists)", flush=True)
                    continue
                try:
                    rec = run_combo(arch, shape, mp, layout=args.layout,
                                    weight_stationary=args.ws, kv8=args.kv8)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "ok": False, "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"dryrun complete; failures={failures}", flush=True)
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
