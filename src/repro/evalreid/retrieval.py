"""Person ReID retrieval evaluation: mAP and CMC (rank-k accuracy).

Query features are matched against a cross-camera gallery by euclidean
distance over L2-normalised features. This numpy path is the per-(client,
task) allclose oracle; production eval runs all (C clients x T tasks)
query sets through ``evalreid.batched.evaluate_retrieval_batched``, whose
distance matrices go through the ``kernels/pairwise_dist`` Pallas kernel
(``kernels.ops.batched_pairwise_dist``).
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def l2_normalize(x, eps=1e-9):
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, eps)


def distance_matrix(qf, gf):
    """Squared euclidean over normalized features: (Q, G)."""
    qf = l2_normalize(np.asarray(qf, np.float32))
    gf = l2_normalize(np.asarray(gf, np.float32))
    # ||q-g||^2 = 2 - 2 q.g for unit vectors
    return 2.0 - 2.0 * (qf @ gf.T)


def evaluate_retrieval(query_feats, query_ids, gallery_feats, gallery_ids,
                       ranks=(1, 3, 5)) -> Dict[str, float]:
    """Returns {"mAP": ..., "R1": ..., "R3": ..., "R5": ...} in [0, 1]."""
    dist = distance_matrix(query_feats, gallery_feats)
    gids = np.asarray(gallery_ids)
    qids = np.asarray(query_ids)
    # stable sort: deterministic tie order, and the same order the batched
    # device path produces (jnp.argsort is stable)
    order = np.argsort(dist, axis=1, kind="stable")
    matches = gids[order] == qids[:, None]          # (Q, G) sorted by rank

    valid = matches.any(axis=1)
    if not valid.any():
        return {"mAP": 0.0, **{f"R{k}": 0.0 for k in ranks}}
    m = matches[valid]

    # mAP
    cum_hits = np.cumsum(m, axis=1)
    ranks_idx = np.arange(1, m.shape[1] + 1)[None, :]
    precision = cum_hits / ranks_idx
    ap = (precision * m).sum(1) / np.maximum(m.sum(1), 1)
    out = {"mAP": float(ap.mean())}
    for k in ranks:
        out[f"R{k}"] = float(m[:, :k].any(axis=1).mean())
    return out
