"""Batched (C clients x T tasks) retrieval evaluation.

Layout: query features are stacked into padded/masked ``(C, T, Q, F)``
arrays (one query set per trained task per client), galleries into
``(C, G, F)`` (one cross-camera gallery per client, padded to a common G).
All C x T mAP/CMC evaluations then run as ONE device program: the distance
matrices go through the ``kernels/pairwise_dist`` Pallas kernel
(``ops.batched_pairwise_dist``), and the ranking/metric math is pure jnp —
an exact replica of ``evalreid.retrieval.evaluate_retrieval``, computed
WITHOUT a full sort. A (Q, G) argsort is the numpy oracle's formulation,
but mAP/CMC only depend on each *matching* gallery item's rank, so we

  1. select each query's matches ordered by (distance, gallery index) with
     one ``lax.top_k`` (its tie rule — lower index first — is exactly the
     oracle's ``kind="stable"`` argsort order);
  2. recover every match's full-gallery rank by *counting* the gallery
     items strictly closer (or equal-distance with a lower index) — an
     exact integer count, so ties resolve identically to the stable sort;
  3. AP = mean over matches of (match position / full rank); R@k = best
     match rank <= k.

This replaces the O(G log G) comparator sort (the CPU bottleneck — XLA's
sort is serial per row) with one top-k plus an O(M·G) vectorized count,
where M = ``max_matches`` is the tiny per-query match bound.

Semantics shared with the oracle: features are L2-normalised, distances
squared euclidean; queries with no gallery match are dropped from every
average; a set with no valid query scores 0.0 across the board. Padded
gallery rows get distance ``_PAD_DIST`` (never closer than a real row) and
sentinel id -1; padded/masked queries get sentinel id -2, so padding can
never match and never shifts a real match's rank.

``evaluate_retrieval_batched(backend="host")`` is the retained numpy
oracle: a Python loop over (c, t) slices calling ``evaluate_retrieval`` on
the unpadded arrays.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import register_program
from repro.evalreid.retrieval import evaluate_retrieval
from repro.kernels import ops

_PAD_DIST = 1e30      # >> max squared distance of unit vectors (4.0)
_PAD_GID = -1
_PAD_QID = -2


def _l2n(x, eps=1e-9):
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


def max_match_bound(qids, gids, *, qmask=None, gmask=None) -> int:
    """Tight host-side bound on per-query gallery matches (the static
    ``max_matches`` for ``batched_retrieval_metrics``): the most often any
    queried identity appears in its client's (valid) gallery."""
    qids, gids = np.asarray(qids), np.asarray(gids)
    best = 1
    for c in range(qids.shape[0]):
        g = gids[c] if gmask is None else gids[c][np.asarray(gmask[c]) > 0]
        q = qids[c].ravel() if qmask is None else \
            qids[c].ravel()[np.asarray(qmask[c]).ravel() > 0]
        q = q[q >= 0]
        if len(g) == 0 or len(q) == 0:
            continue
        vals, cnts = np.unique(g, return_counts=True)
        hit = np.isin(vals, q)
        if hit.any():
            best = max(best, int(cnts[hit].max()))
    return best


def batched_retrieval_metrics(qf, qids, gf, gids, *, qmask=None, gmask=None,
                              ranks: Tuple[int, ...] = (1, 3, 5),
                              backend: Optional[str] = None,
                              max_matches: Optional[int] = None):
    """Traceable batched mAP/CMC — usable inside jit / on a mesh.

    qf: (C, T, Q, F) query features; qids: (C, T, Q) identity ids;
    gf: (C, G, F) gallery features; gids: (C, G) identity ids;
    qmask: (C, T, Q) query validity (None = all valid; combine the task
    mask in here — or pre-sentinel invalid qids to a negative value);
    gmask: (C, G) gallery validity (None = all valid);
    backend: kernel backend for ``ops.batched_pairwise_dist``;
    max_matches: static upper bound on gallery matches per query (see
    ``max_match_bound``; None = G, always safe but does more counting).

    Returns {"mAP": (C, T), "R1": ..., ...} fp32 arrays, averaged over the
    valid queries of each (c, t) set (0.0 where none are valid).
    """
    C, T, Q, F = qf.shape
    G = gf.shape[1]
    M = G if max_matches is None else max(1, min(int(max_matches), G))
    qn = _l2n(qf.astype(jnp.float32))
    gn = _l2n(gf.astype(jnp.float32))
    dist = ops.batched_pairwise_dist(qn.reshape(C, T * Q, F), gn,
                                     backend=backend)
    dist = dist.reshape(C, T, Q, G)

    gids_eff = gids.astype(jnp.int32)
    if gmask is not None:
        gvalid = gmask > 0
        dist = jnp.where(gvalid[:, None, None, :], dist, _PAD_DIST)
        gids_eff = jnp.where(gvalid, gids_eff, _PAD_GID)
    qids_eff = qids.astype(jnp.int32)
    if qmask is not None:
        qids_eff = jnp.where(qmask > 0, qids_eff, _PAD_QID)

    m = gids_eff[:, None, None, :] == qids_eff[..., None]    # (C, T, Q, G)
    n_match = jnp.sum(m.astype(jnp.float32), -1)             # (C, T, Q)

    # matches in stable-sort order: top_k breaks value ties by lower index,
    # exactly the oracle's argsort(kind="stable") order among matches
    neg = jnp.where(m, -dist, -jnp.inf)
    mvals, midx = jax.lax.top_k(neg, M)                      # (C, T, Q, M)
    match_d = -mvals                                         # ascending
    mvalid = mvals > -jnp.inf                                # slot < n_match

    # full-gallery stable rank of match i: 1 + #{closer} + #{tied, earlier}
    # (padding rows sit at _PAD_DIST, never closer / never tied with a real
    # match, so they can't shift a rank — counts are exact integers)
    gdx = jnp.arange(G, dtype=jnp.int32)
    before = ((dist[..., None, :] < match_d[..., None])
              | ((dist[..., None, :] == match_d[..., None])
                 & (gdx < midx[..., None])))
    r = 1.0 + jnp.sum(before.astype(jnp.float32), -1)        # (C, T, Q, M)

    # AP = mean over matches of (position among matches) / (full rank)
    pos = jnp.arange(1, M + 1, dtype=jnp.float32)
    ap = (jnp.sum(jnp.where(mvalid, pos / r, 0.0), -1)
          / jnp.maximum(n_match, 1.0))                       # (C, T, Q)

    valid = n_match > 0
    vf = valid.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(vf, -1), 1.0)                  # (C, T)
    best = r[..., 0]                                         # best match rank
    out = {"mAP": jnp.sum(ap * vf, -1) / cnt}
    for k in ranks:
        hit = (best <= k).astype(jnp.float32)
        out[f"R{k}"] = jnp.sum(hit * vf, -1) / cnt
    return out


def _metrics_abstract():
    """Bench-scale abstract eval inputs: C=100 clients x T=3 tasks."""
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    C, T, Q, G, F = 100, 3, 16, 96, 64
    return ((S((C, T, Q, F), f32), S((C, T, Q), i32), S((C, G, F), f32),
             S((C, G), i32), S((C, T, Q), f32), S((C, G), f32)),
            {"ranks": (1, 3, 5), "backend": "ref", "max_matches": 4})


@register_program(
    "evalreid.batched_retrieval_metrics",
    abstract_args=_metrics_abstract,
    oracle="repro.evalreid.batched._metrics_host", budget_bytes=64 << 20)
@functools.partial(jax.jit,
                   static_argnames=("ranks", "backend", "max_matches"))
def _metrics_device(qf, qids, gf, gids, qmask, gmask, *, ranks, backend,
                    max_matches):
    return batched_retrieval_metrics(qf, qids, gf, gids, qmask=qmask,
                                     gmask=gmask, ranks=ranks,
                                     backend=backend,
                                     max_matches=max_matches)


def _metrics_host(qf, qids, gf, gids, qmask, gmask, ranks):
    """The allclose oracle: per-(c, t) numpy ``evaluate_retrieval`` over
    the unpadded slices."""
    qf, qids = np.asarray(qf), np.asarray(qids)
    gf, gids = np.asarray(gf), np.asarray(gids)
    C, T = qf.shape[:2]
    keys = ["mAP"] + [f"R{k}" for k in ranks]
    out = {k: np.zeros((C, T), np.float32) for k in keys}
    for c in range(C):
        gsel = slice(None) if gmask is None else np.asarray(gmask[c]) > 0
        gfc, gic = gf[c][gsel], gids[c][gsel]
        for t in range(T):
            qsel = (slice(None) if qmask is None
                    else np.asarray(qmask[c, t]) > 0)
            qfc, qic = qf[c, t][qsel], qids[c, t][qsel]
            if len(qfc) == 0 or len(gfc) == 0:
                continue                      # all-invalid set scores 0.0
            m = evaluate_retrieval(qfc, qic, gfc, gic, ranks=ranks)
            for k in keys:
                out[k][c, t] = m[k]
    return out


def evaluate_retrieval_batched(qf, qids, gf, gids, *, qmask=None, gmask=None,
                               ranks: Tuple[int, ...] = (1, 3, 5),
                               backend: str = "device",
                               kernel_backend: Optional[str] = None,
                               max_matches: Optional[int] = None
                               ) -> Dict[str, np.ndarray]:
    """All (c, t) retrieval evaluations at once -> {"mAP": (C, T), ...}.

    ``backend="device"`` runs the single jitted program (distances through
    the Pallas kernel path selected by ``kernel_backend``);
    ``backend="host"`` is the numpy loop-over-(c, t) oracle.
    """
    if backend == "host":
        return _metrics_host(qf, qids, gf, gids, qmask, gmask, tuple(ranks))
    if backend != "device":
        raise ValueError(f"unknown eval backend {backend!r}")
    if max_matches is None:
        max_matches = max_match_bound(qids, gids, qmask=qmask, gmask=gmask)
    out = _metrics_device(
        jnp.asarray(qf), jnp.asarray(qids), jnp.asarray(gf),
        jnp.asarray(gids),
        None if qmask is None else jnp.asarray(qmask),
        None if gmask is None else jnp.asarray(gmask),
        ranks=tuple(ranks), backend=kernel_backend,
        max_matches=int(max_matches))
    return {k: np.asarray(v) for k, v in out.items()}
