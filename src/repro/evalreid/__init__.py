from repro.evalreid.batched import (
    batched_retrieval_metrics,
    evaluate_retrieval_batched,
)
from repro.evalreid.retrieval import (
    distance_matrix,
    evaluate_retrieval,
    l2_normalize,
)
