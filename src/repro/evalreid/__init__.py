from repro.evalreid.retrieval import (
    distance_matrix,
    evaluate_retrieval,
    l2_normalize,
)
