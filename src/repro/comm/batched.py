"""Device-resident batched wire codec for the stacked engine.

``BatchedCodec`` runs the same stage stack as the host ``PipelineCodec``
(delta -> topk -> {int8|bf16}) over ALL C clients' flattened (C, P)
payload rows as one jitted device program — the sparsify/quantize hot
paths are the Pallas kernels in ``kernels/topk_pack.py`` /
``kernels/quantize.py`` (via ``kernels.ops``, so the jnp oracle serves CPU
and the compiled kernel serves TPU). Encoded buffers stay on device; the
measured per-client wire bytes fall out of the buffer shapes, so a
simulated round needs NO host readback at all, and a real dispatch needs
exactly one (the encoded buffers themselves).

Stage semantics are bit-identical to the host codec on CPU (same top-k tie
handling, same round-half-to-even per-chunk scales), which the comm-round
bench asserts (``benchmarks/comm_round.py --smoke``).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codec import PipelineCodec
from repro.kernels import ops


class BatchedCodec:
    """One direction's (C, P) encode/decode program, built from the host
    codec's stage parameters. Stateful only when delta is on (device ref)."""

    def __init__(self, like: PipelineCodec, p: int, *,
                 backend: Optional[str] = None):
        if like.topk and like.group is None:
            raise ValueError(
                "BatchedCodec needs the grouped top-k stage (group=N); "
                "explicit-k global top-k is a host-codec-only mode")
        self.spec = like.spec
        self.delta = like.delta
        self.topk = like.topk
        self.quant = like.quant
        self.chunk = like.chunk
        self.group = like.group
        self.kg = like.kg
        self.p = int(p)
        self.k = like.k_for(self.p) if like.topk else None
        self.backend = backend
        self._enc_ref = None
        self._dec_ref = None
        self.last_metrics = None   # most recent encode's device telemetry

        chunk, quant, topk = self.chunk, self.quant, self.topk
        group, kg = self.group, self.kg

        def _quant(vals, buffers):
            if quant == "int8":
                q, scales = ops.batched_quantize(vals, chunk=chunk,
                                                 backend=backend)
                buffers["values"] = q
                buffers["scales"] = scales
            elif quant == "bf16":
                buffers["values"] = vals.astype(jnp.bfloat16)
            else:
                buffers["values"] = vals
            return buffers

        kk = self.k
        pp = self.p

        # encode telemetry rides the same launch: per-row residual norm
        # (decoder-reference staleness — grows as the ref drifts), the
        # fraction of residual energy the wire kept, and the effective
        # keep-rate. Tiny (C,) outputs of a program that already runs; the
        # host only reads them back when a tracer is active.
        def _enc_metrics(x, vals):
            r2 = jnp.sum(jnp.square(x), axis=1)
            k2 = jnp.sum(jnp.square(vals), axis=1)
            return {"residual_norm": jnp.sqrt(r2),
                    "kept_energy": k2 / jnp.maximum(r2, 1e-12),
                    "keep_rate": jnp.sum(vals != 0, axis=1) / pp}

        @jax.jit
        def _enc_sparse(x):
            vals, idx = ops.batched_topk_pack(x, group=group, kg=kg,
                                              backend=backend)
            packed = ops.batched_idx_bitpack(idx, group=group, kg=kg,
                                             backend=backend)
            return _quant(vals, {"idx_bits": packed}), _enc_metrics(x, vals)

        @jax.jit
        def _enc_dense(x):
            x = x.astype(jnp.float32)
            return _quant(x, {}), _enc_metrics(x, x)

        def _dequant(buffers):
            v = buffers["values"]
            if quant == "int8":
                return ops.batched_dequantize(v, buffers["scales"],
                                              chunk=chunk, backend=backend)
            return v.astype(jnp.float32)

        @jax.jit
        def _dec_sparse(buffers):
            idx = ops.batched_idx_bitunpack(buffers["idx_bits"], k=kk,
                                            group=group, kg=kg,
                                            backend=backend)
            return ops.batched_topk_unpack(_dequant(buffers), idx, p=pp,
                                           group=group, kg=kg,
                                           backend=backend)

        @jax.jit
        def _dec_dense(buffers):
            return _dequant(buffers)

        self._enc_sparse = _enc_sparse
        self._enc_dense = _enc_dense
        self._dec_sparse = _dec_sparse
        self._dec_dense = _dec_dense

    # ---- wire ----------------------------------------------------------------
    def _dec(self, buffers):
        return (self._dec_sparse(buffers) if "idx_bits" in buffers
                else self._dec_dense(buffers))

    def _encode_residual(self, x):
        """Apply the keyframe rule and encode; advances NO state.
        Returns (buffers, delta reference or None). Stores the encode
        launch's rider telemetry in ``self.last_metrics`` (per-row
        residual norm / kept energy / keep-rate, still on device)."""
        if not self.delta:
            buffers, mets = (self._enc_sparse(x) if self.topk
                             else self._enc_dense(x))
            self.last_metrics = mets
            return buffers, None
        keyframe = self._enc_ref is None
        ref = jnp.zeros_like(x) if keyframe else self._enc_ref
        r = x - ref
        buffers, mets = (self._enc_dense(r) if keyframe or not self.topk
                         else self._enc_sparse(r))
        self.last_metrics = mets
        return buffers, ref

    def encode(self, mat) -> Dict[str, jax.Array]:
        """(C, P) stacked payload rows -> dict of device wire buffers.

        Mirrors the host codec's keyframe rule: a delta stream's first
        payload ships dense (quantized only) to establish the reference;
        every later payload is a sparse residual."""
        buffers, ref = self._encode_residual(mat.astype(jnp.float32))
        if self.delta:
            self._enc_ref = ref + self._dec(buffers)
        return buffers

    def decode(self, buffers) -> jax.Array:
        """Wire buffers -> reconstructed (C, P) fp32 rows."""
        x = self._dec(buffers)
        if self.delta:
            x = x if self._dec_ref is None else self._dec_ref + x
            self._dec_ref = x
        return x

    def roundtrip(self, mat):
        """encode + decode in one device pass: (reconstruction, buffers).

        The stacked simulation plays both wire ends, and the encoder's
        error-feedback ref IS the decoder's reconstruction — running the
        unpack+dequant program once per round instead of twice. Both refs
        advance exactly as separate encode()/decode() calls would."""
        buffers, ref = self._encode_residual(mat.astype(jnp.float32))
        recon = self._dec(buffers)
        if self.delta:
            recon = ref + recon
            self._enc_ref = recon
            self._dec_ref = recon
        return recon, buffers

    # ---- accounting ----------------------------------------------------------
    def per_client_bytes(self, buffers) -> int:
        """Measured wire bytes per client (row) from the buffer shapes —
        no readback needed."""
        total = 0
        for b in buffers.values():
            total += int(np.prod(b.shape[1:])) * b.dtype.itemsize
        return total
