from repro.comm.accounting import CommLog, fmt_bytes
from repro.comm.batched import BatchedCodec
from repro.comm.codec import Codec, PipelineCodec, WirePayload, make_codec
