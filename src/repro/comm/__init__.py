from repro.comm.accounting import CommLog, fmt_bytes
