"""Wire-format codec stack: the payloads the comm accounting measures.

``Codec.encode(pytree) -> WirePayload`` materializes the exact buffers a
client/server would put on the wire; ``decode(WirePayload) -> pytree``
reconstructs the (possibly lossy) payload the receiver trains on. Stages
compose in a fixed canonical order over the flattened fp32 payload vector:

    delta  — residual vs the last reconstruction this peer shipped
             (stateful per ``peer``; the encoder tracks the DECODER-visible
             reconstruction, so both sides stay in sync under lossy
             downstream stages — which makes dropped coordinates re-enter
             the next residual: built-in error feedback. Default ON when
             topk is on, see ``make_codec``. A stream's FIRST payload is a
             dense "keyframe" that establishes the reference; every later
             payload is a sparse residual);
    topk   — top-k magnitude sparsification -> (values, indices), ties by
             lowest index. Default form is GROUPED (top-kg within every
             group of 8 contiguous elements — the hardware-friendly budget
             the Pallas kernels implement, see ``kernels/topk_pack.py``),
             whose indices ship BIT-PACKED (only the 3-bit local in-group
             index per slot; the group base is slot arithmetic — 10.7x
             fewer index bytes than int32); an explicit ``k`` selects
             exact global top-k (numpy introselect, host-only — plain
             int32 indices, what FedWeIT's sparse-bytes formula models);
    int8   — per-chunk symmetric int8 quantization of the surviving values
             (one fp32 scale per ``chunk`` elements; round-half-to-even),
    bf16   — alternative 2-byte lossy cast (no scales).

``make_codec("topk+int8")`` parses a ``+``-joined spec into a
``PipelineCodec``; ``WirePayload.nbytes`` is the measured byte count the
simulation logs (formulas stay as the cross-check oracle — see
``comm.accounting``). The host codec is pure numpy; the stacked engine
runs the same stages as one jitted device program over all C clients
(``comm.batched.BatchedCodec``, backed by the Pallas kernels in
``kernels/quantize.py`` and ``kernels/topk_pack.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_KEEP_FRAC = 0.35
DEFAULT_CHUNK = 256
DEFAULT_GROUP = 8

_STAGES = ("raw", "delta", "topk", "int8", "bf16")


@dataclasses.dataclass
class WirePayload:
    """One encoded payload: named wire buffers + the schema to decode them.

    ``nbytes`` counts the buffers only — the schema (tree structure, sizes)
    is per-connection setup traffic, not per-round payload."""

    buffers: Dict[str, np.ndarray]
    schema: Dict[str, Any]

    @property
    def nbytes(self) -> int:
        return int(sum(b.nbytes for b in self.buffers.values()))


def _flatten_host(tree) -> Tuple[np.ndarray, tuple]:
    """Pytree -> (fp32 vector, meta). Row layout matches
    ``common.pytree.tree_flatten_concat`` (leaf order of jax.tree.flatten)."""
    leaves, treedef = jax.tree.flatten(tree)
    arrs = [np.asarray(l) for l in leaves]
    meta = (treedef, [a.shape for a in arrs], [a.dtype for a in arrs])
    if not arrs:
        return np.zeros((0,), np.float32), meta
    return np.concatenate([a.ravel().astype(np.float32) for a in arrs]), meta


def _unflatten_host(flat: np.ndarray, meta) -> Any:
    treedef, shapes, dtypes = meta
    leaves, off = [], 0
    for s, dt in zip(shapes, dtypes):
        n = int(np.prod(s)) if len(s) else 1
        leaves.append(flat[off:off + n].reshape(s).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, leaves)


def topk_select_host(x: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Exact GLOBAL top-k by magnitude over a host vector: (values fp32,
    indices int32), ascending index order, ties at the k-th magnitude kept
    by lowest index. numpy's introselect makes this cheap on host; it is
    the selection the FedWeIT ``sparse_bytes`` formula models (and the
    codec mode an explicit ``k`` requests). The device path uses the
    grouped variant below — identical byte counts at the same keep
    fraction, hardware-friendly selection."""
    k = min(k, x.size)
    if k == 0:
        return np.zeros((0,), np.float32), np.zeros((0,), np.int32)
    absx = np.abs(x)
    thr = np.partition(absx, x.size - k)[x.size - k]
    keep = absx > thr
    n_above = int(keep.sum())
    if n_above < k:
        ties = np.flatnonzero(absx == thr)[:k - n_above]
        keep[ties] = True
    idx = np.flatnonzero(keep).astype(np.int32)
    return x[idx].astype(np.float32), idx


def grouped_topk_select_host(x: np.ndarray, group: int,
                             kg: int) -> Tuple[np.ndarray, np.ndarray]:
    """Grouped top-k over a host vector: every group of ``group``
    contiguous elements keeps its ``kg`` largest magnitudes (ties by
    lowest index), packed in magnitude-rank order. Identical counting
    formulas — and therefore bit-identical output — to
    ``kernels.ref.batched_topk_pack_ref`` / the Pallas pack kernel."""
    P = x.size
    nb = (P + group - 1) // group
    xp = np.zeros((nb * group,), np.float32)
    xp[:P] = x
    xg = xp.reshape(nb, group)
    a = np.abs(xg)
    ii = np.arange(group)
    beats = (a[:, None, :] > a[:, :, None]) | (
        (a[:, None, :] == a[:, :, None]) & (ii[None, :] < ii[:, None]))
    rank = beats.sum(-1)                                   # (nb, G)
    onehot = rank[..., None] == np.arange(kg)              # (nb, G, kg)
    vals = np.sum(xg[..., None] * onehot, axis=1, dtype=np.float32)
    gidx = (np.arange(nb)[:, None] * group + ii[None, :])
    idx = np.sum(gidx[..., None] * onehot, axis=1).astype(np.int32)
    return vals.reshape(-1), idx.reshape(-1)


def pack_group_indices_host(idx: np.ndarray, group: int,
                            kg: int) -> np.ndarray:
    """Bit-pack grouped top-k indices for the wire: (K,) int32 absolute
    indices (from ``grouped_topk_select_host``, slot s in group s // kg)
    -> (bits * ceil(K/8),) uint8, bits = ceil(log2(group)) (3 at group=8 —
    a 10.7x shrink vs int32). Only the local in-group index is entropy;
    the group base is slot-position arithmetic on the receiver. Bitplane-
    major layout, identical to ``kernels.ref.batched_idx_bitpack_ref`` /
    the Pallas kernel, so host and batched wire bytes stay equal."""
    bits = (group - 1).bit_length()
    K = idx.size
    kb = (K + 7) // 8
    li = idx.astype(np.int32) - (np.arange(K, dtype=np.int32) // kg) * group
    lip = np.zeros((kb * 8,), np.int32)
    lip[:K] = li
    lib = lip.reshape(kb, 8)
    lane = (1 << np.arange(8)).astype(np.int32)
    planes = [(((lib >> j) & 1) * lane).sum(1) for j in range(bits)]
    return np.concatenate(planes).astype(np.uint8)


def unpack_group_indices_host(packed: np.ndarray, k: int, group: int,
                              kg: int) -> np.ndarray:
    """Inverse of ``pack_group_indices_host``: uint8 bitplanes -> (k,)
    int32 absolute indices."""
    bits = (group - 1).bit_length()
    kb = packed.size // bits
    b = packed.reshape(bits, kb).astype(np.int32)
    flat = ((b[:, :, None] >> np.arange(8)) & 1).reshape(bits, kb * 8)[:, :k]
    li = np.zeros((k,), np.int32)
    for j in range(bits):
        li += flat[j] << j
    return (np.arange(k, dtype=np.int32) // kg) * group + li


def quantize_host(v: np.ndarray, chunk: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-chunk symmetric int8: (n,) fp32 -> ((n,) int8, per-chunk fp32
    scales). Same math as ``kernels.ref.batched_quantize_ref``."""
    n = v.size
    nc = (n + chunk - 1) // chunk          # 0 chunks for an empty payload
    vp = np.zeros((nc * chunk,), np.float32)
    vp[:n] = v
    vc = vp.reshape(nc, chunk)
    absmax = np.max(np.abs(vc), axis=1, keepdims=True)
    scale = (absmax / 127.0).astype(np.float32)
    scale = np.where(scale > 0, scale, np.float32(1.0))   # 0 / subnormal
    q = np.clip(np.rint(vc / scale), -127.0, 127.0).astype(np.int8)
    return q.reshape(-1)[:n], scale[:, 0]


def dequantize_host(q: np.ndarray, scales: np.ndarray,
                    chunk: int) -> np.ndarray:
    n = q.size
    nc = scales.size
    qp = np.zeros((nc * chunk,), np.float32)
    qp[:n] = q.astype(np.float32)
    out = qp.reshape(nc, chunk) * scales[:, None]
    return out.reshape(-1)[:n]


class Codec:
    """Interface: one bidirectional wire format."""

    spec: str = "raw"

    def encode(self, tree, peer=None) -> WirePayload:
        raise NotImplementedError

    def decode(self, payload: WirePayload, peer=None):
        raise NotImplementedError


class PipelineCodec(Codec):
    """The composable delta -> topk -> {int8|bf16} stack (any subset).

    ``keep_frac`` sizes the grouped budget as kg = round(keep_frac * group)
    kept entries per group (an explicit ``k`` switches to exact global
    top-k with ``max(1, int(keep_frac * P))``-style sizing, matching
    FedWeIT's accounting). Stateful only when ``delta`` is on: per-``peer``
    encoder/decoder reference vectors track the reconstruction each side
    has seen (first payload per peer = dense keyframe).
    """

    def __init__(self, spec: str, *, delta: bool = False,
                 topk: bool = False, keep_frac: float = DEFAULT_KEEP_FRAC,
                 k: Optional[int] = None, group: Optional[int] = DEFAULT_GROUP,
                 quant: Optional[str] = None, chunk: int = DEFAULT_CHUNK):
        if quant not in (None, "int8", "bf16"):
            raise ValueError(f"unknown quant stage {quant!r}")
        self.spec = spec
        self.delta = delta
        self.topk = topk
        self.keep_frac = keep_frac
        self.k = k
        # explicit k selects exact GLOBAL top-k (host-only codec mode, the
        # FedWeIT formula check); otherwise the grouped budget applies
        self.group = None if k is not None else group
        self.kg = (max(1, int(round(keep_frac * group)))
                   if self.group else None)
        self.quant = quant
        self.chunk = chunk
        self._enc_ref: Dict[Any, np.ndarray] = {}
        self._dec_ref: Dict[Any, np.ndarray] = {}

    def k_for(self, p: int) -> int:
        """Total kept entries for a payload of p elements."""
        if self.group is not None:
            return ((p + self.group - 1) // self.group) * self.kg
        if self.k is not None:
            return min(self.k, p)
        return min(p, max(1, int(self.keep_frac * p)))

    # ---- encode --------------------------------------------------------------
    def encode(self, tree, peer=None) -> WirePayload:
        payload, ref = self._build(tree, peer)
        if self.delta:
            # advance the encoder ref by what the DECODER will reconstruct,
            # so lossy stages never let the two sides drift
            self._enc_ref[peer] = ref + self._decode_residual(payload)
        return payload

    def roundtrip(self, tree, peer=None):
        """encode + decode in one pass: (decoded tree, payload).

        The simulation plays both wire ends in-process, and the
        reconstruction that advances the encoder's error-feedback ref IS
        the decoder's output — computing it once instead of per side
        halves the decode work on the hot path. Both refs advance exactly
        as separate encode()/decode() calls would."""
        payload, ref = self._build(tree, peer)
        recon = self._decode_residual(payload)
        if self.delta:
            recon = ref + recon
            self._enc_ref[peer] = recon
            self._dec_ref[peer] = recon
        return _unflatten_host(recon, payload.schema["tree"]), payload

    def _build(self, tree, peer) -> Tuple[WirePayload, Optional[np.ndarray]]:
        """Encode ``tree`` into a payload WITHOUT advancing delta state;
        returns (payload, the delta reference used or None)."""
        flat, meta = _flatten_host(tree)
        P = flat.size
        schema: Dict[str, Any] = {"codec": self.spec, "P": P, "tree": meta,
                                  "chunk": self.chunk}
        x = flat
        ref = None
        keyframe = False
        if self.delta:
            ref = self._enc_ref.get(peer)
            # keyframe: the stream's first payload establishes the
            # reference DENSE (quantized only) — sparsifying an absolute
            # payload drops uniformly-important entries (BN scales) and the
            # early-round damage never heals (measured: -33 mAP on the
            # synthetic bench). Every later round is a sparse residual.
            keyframe = ref is None
            if ref is None:
                ref = np.zeros_like(flat)
            x = flat - ref
        buffers: Dict[str, np.ndarray] = {}
        sparse = self.topk and not keyframe
        schema["sparse"] = sparse
        if sparse:
            schema["k"] = self.k_for(P)
            schema["group"] = self.group
            if self.group is not None:
                # grouped indices ship bit-packed (3 bits/slot at group=8);
                # global top-k keeps plain int32 (arbitrary positions — the
                # FedWeIT nnz * (4 + 4) formula models exactly that)
                schema["kg"] = self.kg
                vals, idx = grouped_topk_select_host(x, self.group, self.kg)
                buffers["idx_bits"] = pack_group_indices_host(
                    idx, self.group, self.kg)
            else:
                vals, idx = topk_select_host(x, schema["k"])
                buffers["indices"] = idx
        else:
            vals = x.astype(np.float32)
        if self.quant == "int8":
            q, scales = quantize_host(vals, self.chunk)
            buffers["values"] = q
            buffers["scales"] = scales
        elif self.quant == "bf16":
            buffers["values"] = np.asarray(vals, dtype=jnp.bfloat16)
        else:
            buffers["values"] = vals
        return WirePayload(buffers, schema), ref

    # ---- decode --------------------------------------------------------------
    def _decode_residual(self, payload: WirePayload) -> np.ndarray:
        schema = payload.schema
        v = payload.buffers["values"]
        if self.quant == "int8":
            v = dequantize_host(v, payload.buffers["scales"], schema["chunk"])
        else:
            v = np.asarray(v, np.float32)
        if schema["sparse"]:
            P = schema["P"]
            g = schema.get("group")
            if g is not None:
                idx = unpack_group_indices_host(
                    payload.buffers["idx_bits"], schema["k"], g, schema["kg"])
                Pp = ((P + g - 1) // g) * g           # grouped: padded tail
            else:
                idx = payload.buffers["indices"]
                Pp = P
            dense = np.zeros((Pp,), np.float32)
            dense[idx] = v
            return dense[:P]
        return v

    def decode(self, payload: WirePayload, peer=None):
        x = self._decode_residual(payload)
        if self.delta:
            ref = self._dec_ref.get(peer)
            x = x if ref is None else ref + x
            self._dec_ref[peer] = x
        return _unflatten_host(x, payload.schema["tree"])


def make_codec(spec: Optional[str], **overrides) -> Optional[Codec]:
    """Parse a ``+``-joined stage spec ("raw", "int8", "topk+int8",
    "delta+topk+int8", ...) into a fresh ``PipelineCodec`` (None -> None).
    ``overrides``: keep_frac, k, chunk, delta.

    Default knob: ``topk`` implies ``delta`` (override with
    ``delta=False``). Stateless top-k of *absolute* parameters is
    systematically destructive — the receiver aggregates a mostly-zero
    tensor, shrinking every aggregate entry (measured on the synthetic
    bench: -4.6 mAP at keep_frac=0.25) — whereas top-k of the residual vs
    the decoder-visible reconstruction is self-correcting: dropped
    coordinates stay in the next residual until shipped (error feedback
    for free), and the reconstruction converges to the true stream at
    ~keep_frac coverage per round. Same wire format either way.
    """
    if spec is None:
        return None
    stages = [s.strip() for s in spec.split("+") if s.strip()]
    unknown = [s for s in stages if s not in _STAGES]
    if unknown:
        raise ValueError(f"unknown codec stage(s) {unknown} in {spec!r}; "
                         f"known: {_STAGES}")
    quants = [s for s in stages if s in ("int8", "bf16")]
    if len(quants) > 1:
        raise ValueError(f"at most one quantization stage, got {quants}")
    topk = "topk" in stages
    delta = overrides.pop("delta", "delta" in stages or topk)
    return PipelineCodec(
        spec,
        delta=delta,
        topk=topk,
        quant=quants[0] if quants else None,
        **overrides,
    )
