"""Communication-cost accounting: measured wire bytes + analytic formulas.

Two parallel per-round ledgers per direction (S2C / C2S, paper Table II):

  * **wire** (``c2s`` / ``s2c``) — the bytes that actually move. When a
    strategy carries wire codecs (``Strategy(codec="topk+int8")``), these
    are the MEASURED sizes of the encoded ``WirePayload`` buffers (plus any
    verbatim control tensors); without codecs they equal the formulas, so
    pre-codec callers see identical totals.
  * **formula** (``c2s_formula`` / ``s2c_formula``) — the analytic payload
    formulas (``tree_bytes``, FedWeIT's ``nnz * (4 + 4)``), always
    recorded. They are the cross-check oracle for the measured path: the
    codec tests assert formula ~= measured for the stages the formulas
    model, and ``round_breakdown()`` exposes both so Fig. 8 reproduction
    reports measured traffic next to what the paper's accounting assumes.

``measured`` stays False until the first measured log, so ``total`` keeps
its historical meaning (formula bytes) for codec-less runs.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional

from repro.common.pytree import tree_bytes


@dataclasses.dataclass
class CommLog:
    def __post_init__(self):
        self.c2s: Dict[int, int] = defaultdict(int)   # wire bytes per round
        self.s2c: Dict[int, int] = defaultdict(int)
        self.c2s_formula: Dict[int, int] = defaultdict(int)
        self.s2c_formula: Dict[int, int] = defaultdict(int)
        self.measured = False                         # any measured log yet?

    @staticmethod
    def _size(payload) -> int:
        return payload if isinstance(payload, int) else tree_bytes(payload)

    def _log(self, wire, formula, rnd, payload, n, measured, n_formula):
        f = self._size(payload)
        formula[rnd] += (n if n_formula is None else n_formula) * f
        if measured is None:
            wire[rnd] += n * f
        else:
            wire[rnd] += n * int(measured)
            self.measured = True

    def log_c2s(self, rnd: int, payload, measured: Optional[int] = None):
        """``payload``: pytree or formula byte count; ``measured``: the
        encoded WirePayload's byte count (None = no codec, wire=formula)."""
        self._log(self.c2s, self.c2s_formula, rnd, payload, 1, measured, None)

    def log_s2c(self, rnd: int, payload, measured: Optional[int] = None):
        self._log(self.s2c, self.s2c_formula, rnd, payload, 1, measured, None)

    # batched logging: the stacked engine moves C identical-size payloads
    # per round — one accounting call instead of a per-client Python loop
    # (``payload``/``measured`` are per-client sizes, counted n times;
    # ``n_formula`` lets the formula oracle keep its own multiplicity when
    # the wire model ships a different number of copies, e.g. the stacked
    # broadcast dispatch stream vs the host engine's per-client dispatches)
    def log_c2s_many(self, rnd: int, payload, n: int,
                     measured: Optional[int] = None,
                     n_formula: Optional[int] = None):
        self._log(self.c2s, self.c2s_formula, rnd, payload, n, measured,
                  n_formula)

    def log_s2c_many(self, rnd: int, payload, n: int,
                     measured: Optional[int] = None,
                     n_formula: Optional[int] = None):
        self._log(self.s2c, self.s2c_formula, rnd, payload, n, measured,
                  n_formula)

    # ---- totals (wire = measured when codecs are active) ---------------------
    @property
    def total_c2s(self) -> int:
        return sum(self.c2s.values())

    @property
    def total_s2c(self) -> int:
        return sum(self.s2c.values())

    @property
    def total(self) -> int:
        return self.total_c2s + self.total_s2c

    @property
    def total_c2s_formula(self) -> int:
        return sum(self.c2s_formula.values())

    @property
    def total_s2c_formula(self) -> int:
        return sum(self.s2c_formula.values())

    @property
    def total_formula(self) -> int:
        return self.total_c2s_formula + self.total_s2c_formula

    def round_breakdown(self) -> List[Dict[str, int]]:
        """Per-round measured-vs-formula rows, sorted by round."""
        rounds = sorted(set(self.c2s) | set(self.s2c)
                        | set(self.c2s_formula) | set(self.s2c_formula))
        return [{"round": r,
                 "c2s_wire": self.c2s.get(r, 0),
                 "s2c_wire": self.s2c.get(r, 0),
                 "c2s_formula": self.c2s_formula.get(r, 0),
                 "s2c_formula": self.s2c_formula.get(r, 0)}
                for r in rounds]


def fmt_bytes(n: int) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"
