"""Communication-cost accounting (paper Table II: S2C / C2S columns).

Every strategy reports the exact payload pytrees it moves; we count bytes.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict

from repro.common.pytree import tree_bytes


@dataclasses.dataclass
class CommLog:
    def __post_init__(self):
        self.c2s: Dict[int, int] = defaultdict(int)   # per round
        self.s2c: Dict[int, int] = defaultdict(int)

    def log_c2s(self, rnd: int, payload):
        self.c2s[rnd] += tree_bytes(payload) if not isinstance(payload, int) else payload

    def log_s2c(self, rnd: int, payload):
        self.s2c[rnd] += tree_bytes(payload) if not isinstance(payload, int) else payload

    # batched logging: the stacked engine moves C identical-size payloads
    # per round — one accounting call instead of a per-client Python loop
    def log_c2s_many(self, rnd: int, payload, n: int):
        self.c2s[rnd] += n * (tree_bytes(payload)
                              if not isinstance(payload, int) else payload)

    def log_s2c_many(self, rnd: int, payload, n: int):
        self.s2c[rnd] += n * (tree_bytes(payload)
                              if not isinstance(payload, int) else payload)

    @property
    def total_c2s(self) -> int:
        return sum(self.c2s.values())

    @property
    def total_s2c(self) -> int:
        return sum(self.s2c.values())

    @property
    def total(self) -> int:
        return self.total_c2s + self.total_s2c


def fmt_bytes(n: int) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"
