"""Minimal optax-style optimizer library (pure JAX, no external deps).

Implements Adam/AdamW/SGD, global-norm clipping, and schedules — the paper
trains with Adam(lr=1e-3, weight_decay=1e-5).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params) -> (updates, state)


def adam(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
         schedule: Optional[Callable] = None):
    def init(params):
        return {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        count = state["count"] + 1
        step_lr = lr * (schedule(count) if schedule else 1.0)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c

        def upd(mm, vv, p):
            u = -step_lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            if weight_decay and p is not None:
                u = u - step_lr * weight_decay * p
            return u

        if params is None:
            updates = jax.tree.map(lambda mm, vv: upd(mm, vv, None), m, v)
        else:
            updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def sgd(lr=1e-2, momentum=0.0):
    def init(params):
        return {"mom": jax.tree.map(jnp.zeros_like, params)} if momentum else {}

    def update(grads, state, params=None):
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state["mom"], grads)
            return jax.tree.map(lambda m: -lr * m, mom), {"mom": mom}
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = c / jnp.maximum(warmup, 1)
        prog = jnp.clip((c - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(c < warmup, warm, cos)
    return fn
