"""Federated lifelong metrics (paper Eq. 7 & 8).

Accuracy A_c^(r): average retrieval accuracy over all tasks client c has
trained on, evaluated at round r. Forgetting F_c^(r): mean drop from each
task's historical best to its current accuracy (last task excluded).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class LifelongTracker:
    """Tracks per-(client, task) accuracy across rounds."""

    n_clients: int

    def __post_init__(self):
        # acc[c][task_idx] = list of (round, {metric: value})
        self.records: List[Dict[int, List]] = [dict() for _ in range(self.n_clients)]

    def record(self, client: int, task_idx: int, rnd: int, metrics: Dict[str, float]):
        self.records[client].setdefault(task_idx, []).append((rnd, metrics))

    def accuracy(self, client: int, rnd: int, key: str = "mAP") -> float:
        """Eq. (7): mean over trained tasks of their accuracy at round rnd."""
        vals = []
        for task_idx, hist in self.records[client].items():
            upto = [m[key] for (r, m) in hist if r <= rnd]
            if upto:
                vals.append(upto[-1])
        return float(np.mean(vals)) if vals else 0.0

    def forgetting(self, client: int, rnd: int, key: str = "mAP") -> float:
        """Eq. (8): mean over past tasks of (best-so-far - current)."""
        drops = []
        tasks = sorted(self.records[client])
        if len(tasks) < 2:
            return 0.0
        for task_idx in tasks[:-1]:
            hist = [(r, m[key]) for (r, m) in self.records[client][task_idx]
                    if r <= rnd]
            if len(hist) < 1:
                continue
            vals = [v for _, v in hist]
            drops.append(max(vals) - vals[-1])
        return float(np.mean(drops)) if drops else 0.0

    def mean_accuracy(self, rnd: int, key: str = "mAP") -> float:
        return float(np.mean([self.accuracy(c, rnd, key)
                              for c in range(self.n_clients)]))

    def mean_forgetting(self, rnd: int, key: str = "mAP") -> float:
        return float(np.mean([self.forgetting(c, rnd, key)
                              for c in range(self.n_clients)]))
