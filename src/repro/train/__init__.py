from repro.train.metrics import LifelongTracker
from repro.train.optimizer import (
    adam,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    sgd,
)
from repro.train.trainer import (
    TrainState,
    init_train_state,
    make_full_train_step,
    make_train_step,
)
