"""LM trainer for the assigned architectures (FedSTIL split: frozen trunk,
adaptive last block + head with theta = B ⊙ alpha + A).

This is the edge-client training step at architecture scale — the dry-run
lowers exactly this function over the production mesh. On CPU it drives the
reduced configs (smoke tests, quickstart, e2e driver).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.axes import AxisCtx, UNSHARDED
from repro.configs.base import ModelConfig
from repro.core.adaptive import combine, init_adaptive, merge_params, split_params
from repro.models import lm
from repro.train.optimizer import adam, apply_updates, clip_by_global_norm


@dataclasses.dataclass
class TrainState:
    frozen: Any                # extraction-layer params (never updated)
    B: Any                     # server-provided base for adaptive layers
    trainable: Any             # {"alpha": ..., "A": ...}
    opt_state: Any

    def theta(self):
        return combine(self.B, self.trainable["alpha"], self.trainable["A"])

    def full_params(self):
        return merge_params(self.frozen, self.theta())


def init_train_state(cfg: ModelConfig, key, tp: int = 1,
                     optimizer=None) -> TrainState:
    params = lm.init_params(cfg, key, tp=tp)
    frozen, adaptive = split_params(cfg, params)
    ad = init_adaptive(adaptive)
    opt = optimizer or adam(lr=1e-3, weight_decay=1e-5)
    return TrainState(frozen=frozen, B=ad.B,
                      trainable=ad.trainable(),
                      opt_state=opt.init(ad.trainable()))


def make_train_step(cfg: ModelConfig, optimizer=None, ax: AxisCtx = UNSHARDED,
                    *, window: int = 0, tie_lambda: float = 0.0):
    """Returns train_step(frozen, B, trainable, opt_state, batch) ->
    (trainable, opt_state, metrics). Grads flow only into (alpha, A):
    the trunk is frozen (FedSTIL extraction layers) so backprop stops at the
    adaptive block — the paper's edge-compute-saving property."""
    opt = optimizer or adam(lr=1e-3, weight_decay=1e-5)

    def train_step(frozen, B, trainable, opt_state, batch):
        def lf(tr):
            theta = combine(B, tr["alpha"], tr["A"])
            params = merge_params(frozen, theta)
            total, (ce, aux) = lm.loss_fn(cfg, params, batch, ax, window=window)
            # global-batch mean INSIDE the differentiated function: grads of
            # data-replicated params are auto-psum'd over the data axis by
            # the shard_map transpose, so the mean must be taken here, not
            # applied to the grads afterwards.
            total = ax.pmean_dp(total)
            reported = total
            if tie_lambda:
                # l1 over *local* shards: its gradient (elementwise sign) is
                # correct under any sharding; the scalar itself is
                # shard-varying, so it is excluded from reported metrics.
                l1 = sum(jnp.sum(jnp.abs(a)) for a in jax.tree.leaves(tr["A"]))
                total = total + tie_lambda * l1
            return total, (reported, ax.pmean_dp(ce), ax.pmean_dp(aux))

        (_, (loss, ce, aux)), grads = jax.value_and_grad(lf, has_aux=True)(trainable)
        if ax.tp is None:
            # grad leaves are TP-sharded on the mesh: a local global-norm
            # would be wrong there, so clip only in the unsharded regime
            grads, gnorm = clip_by_global_norm(grads, 1.0)
        else:
            gnorm = jnp.zeros(())
        updates, opt_state = opt.update(grads, opt_state, trainable)
        trainable = apply_updates(trainable, updates)
        metrics = {"loss": loss, "ce": ce, "moe_aux": aux, "grad_norm": gnorm}
        return trainable, opt_state, metrics

    return train_step


def make_full_train_step(cfg: ModelConfig, optimizer=None,
                         ax: AxisCtx = UNSHARDED, *, window: int = 0):
    """Beyond-paper: full fine-tuning of every parameter (used by the e2e
    ~100M driver and available via launch/train.py --full)."""
    opt = optimizer or adam(lr=3e-4)

    def train_step(params, opt_state, batch):
        def lf(p):
            total, (ce, aux) = lm.loss_fn(cfg, p, batch, ax, window=window)
            return ax.pmean_dp(total), (ax.pmean_dp(ce), aux)
        (loss, (ce, aux)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if ax.tp is None:
            grads, gnorm = clip_by_global_norm(grads, 1.0)
        else:
            gnorm = jnp.zeros(())
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "ce": ce, "grad_norm": gnorm}

    return train_step
